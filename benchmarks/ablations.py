"""Beyond-paper ablations of the three CroSatFL mechanisms.

  1. StarMask policy: trained RL policy vs untrained vs greedy fallback —
     terminal reward (Eq. 17) on held-out instances.
  2. Skip-One: on vs off — per-session train energy + compute barrier.
  3. random-k: k_nbr in {0, 1, 2, 4} — rounds-to-accuracy (k_nbr=0
     disables cross-aggregation entirely: clusters drift).

    PYTHONPATH=src python -m benchmarks.ablations [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from benchmarks.common import BenchSetup, print_csv, save_rows
from repro.core.session import Session, SessionConfig
from repro.core.skipone import SkipOneParams
from repro.core.starmask import (Instance, StarMaskParams, cluster,
                                 greedy_fallback, reward, train_policy)
from repro.obs import get_logger

log = get_logger("benchmarks.ablations")


def make_instances(n_sats, count, seed0=100):
    out = []
    for s in range(count):
        rng = np.random.default_rng(seed0 + s)
        out.append(Instance(
            share=rng.dirichlet(np.ones(n_sats)),
            hw=rng.integers(0, 2, n_sats),
            t_comp=rng.lognormal(2.0, 0.6, n_sats),
            e_train=rng.lognormal(4.0, 0.5, n_sats),
            fanout=rng.integers(3, 8, n_sats),
            lisl_e=rng.uniform(1, 5, (n_sats, n_sats))))
    return out


def ablate_starmask(n_sats=20, episodes=150):
    p = StarMaskParams(k_max=8, m_min=2)
    train_insts = make_instances(n_sats, 4, seed0=0)
    test_insts = make_instances(n_sats, 6, seed0=500)
    params, hist = train_policy(train_insts, p, jax.random.PRNGKey(0),
                                episodes=episodes)
    rows = []
    for variant in ("greedy-fallback", "rl-untrained", "rl-trained"):
        rewards = []
        for i, inst in enumerate(test_insts):
            if variant == "greedy-fallback":
                cl = greedy_fallback(inst, p)
                r, _ = reward(cl, inst, p)
            else:
                pp = params if variant == "rl-trained" else None
                res = cluster(inst, p, jax.random.PRNGKey(i), params=pp,
                              n_samples=6)
                r = res.reward
            rewards.append(r)
        rows.append({"mechanism": "starmask", "variant": variant,
                     "mean_reward": float(np.mean(rewards)),
                     "std": float(np.std(rewards))})
        log.info(f"starmask {variant:16s} reward {np.mean(rewards):+.4f} "
                 f"± {np.std(rewards):.4f}")
    return rows


def ablate_skipone(setup: BenchSetup):
    rows = []
    for on in (True, False):
        env, model = setup.build()
        cfg = setup.session_config(model)
        if not on:
            cfg = dataclasses.replace(
                cfg, skip_one=SkipOneParams(theta_T=0, theta_E=0,
                                            theta_H=1e9))  # never skips
        _, ledger, _ = Session(cfg, env, model).run()
        rows.append({"mechanism": "skip-one", "variant": "on" if on else "off",
                     "train_energy_kj": ledger.train_energy_j / 1e3,
                     "compute_time_s": ledger.compute_time_s})
        log.info(f"skip-one {'on ' if on else 'off'}: "
                 f"E={ledger.train_energy_j/1e3:.3f}kJ "
                 f"barrier={ledger.compute_time_s:.1f}s")
    assert rows[0]["compute_time_s"] <= rows[1]["compute_time_s"] + 1e-9
    return rows


def ablate_knbr(setup: BenchSetup):
    rows = []
    for k_nbr in (0, 1, 2, 4):
        s = dataclasses.replace(setup, k_nbr=k_nbr)
        env, model = s.build()
        sess = Session(s.session_config(model), env, model)
        _, ledger, hist = sess.run(eval_fn=lambda p, r: model.evaluate(p))
        rows.append({"mechanism": "random-k", "variant": f"k={k_nbr}",
                     "final_acc": hist[-1]["acc"],
                     "inter_lisl": ledger.inter_lisl_count})
        log.info(f"random-k k_nbr={k_nbr}: acc={hist[-1]['acc']:.3f} "
                 f"inter-LISL={ledger.inter_lisl_count}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rows = []
    rows += ablate_starmask(n_sats=12 if args.quick else 20,
                            episodes=40 if args.quick else 150)
    setup = BenchSetup(dataset="eurosat-sim",
                       n_clients=8 if args.quick else 20,
                       n_train=600 if args.quick else 2000,
                       rounds=3 if args.quick else 10,
                       local_epochs=1 if args.quick else 3,
                       k_max=4 if args.quick else 8)
    rows += ablate_skipone(setup)
    rows += ablate_knbr(setup)
    save_rows("ablations_quick" if args.quick else "ablations", rows)
    for mech in ("starmask", "skip-one", "random-k"):
        print_csv([r for r in rows if r["mechanism"] == mech])


if __name__ == "__main__":
    main()
