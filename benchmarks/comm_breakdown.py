"""Table II: breakdown of LISL/GS communication counts, energy, and
waiting time (EuroSAT setting). Reproduces the paper's headline numbers:
GS communications two orders of magnitude down, GS transmission energy
~6x down, waiting time from hundreds of hours to single digits.

    PYTHONPATH=src python -m benchmarks.comm_breakdown [--quick]
"""
from __future__ import annotations

import argparse

from benchmarks.common import (BenchSetup, print_csv, run_baseline,
                               run_crosatfl, save_rows)
from repro.fl.baselines import BASELINES
from repro.obs import get_logger

log = get_logger("benchmarks.comm_breakdown")


def run(rounds, n_train, n_clients, local_epochs):
    setup = BenchSetup(dataset="eurosat-sim", iid=True, rounds=rounds,
                       n_train=n_train, n_clients=n_clients,
                       local_epochs=local_epochs)
    rows = []
    for method in list(BASELINES) + ["CroSatFL"]:
        if method == "CroSatFL":
            _, ledger, _ = run_crosatfl(setup, eval_every=False)
        else:
            _, ledger, _ = run_baseline(method, setup, eval_every=False)
        row = {"method": method}
        row.update(ledger.row())
        rows.append(row)
        log.info(f"{method:10s} intra={row['intra_lisl']:5d} "
                 f"inter={row['inter_lisl']:5d} gs={row['gs_comm']:5d} "
                 f"txE={row['tx_energy_kj']:8.2f}kJ "
                 f"trainE={row['train_energy_kj']:8.2f}kJ "
                 f"wait={row['waiting_h']:8.2f}h")
    # headline ratios vs FedSyn (paper: >100x GS count, ~6x GS energy)
    base = next(r for r in rows if r["method"] == "FedSyn")
    ours = next(r for r in rows if r["method"] == "CroSatFL")
    log.info(f"GS-comm reduction vs FedSyn: "
             f"{base['gs_comm'] / max(ours['gs_comm'], 1):.1f}x")
    log.info(f"Tx-energy reduction vs FedSyn: "
             f"{base['tx_energy_kj'] / max(ours['tx_energy_kj'], 1e-9):.1f}x")
    log.info(f"Waiting-time reduction vs FedSyn: "
             f"{base['waiting_h'] / max(ours['waiting_h'], 1e-9):.1f}x")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        rows = run(rounds=4, n_train=800, n_clients=10, local_epochs=1)
    else:
        rows = run(rounds=40, n_train=2400, n_clients=40, local_epochs=3)
    save_rows("comm_breakdown", rows)
    print_csv(rows)


if __name__ == "__main__":
    main()
