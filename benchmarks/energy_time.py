"""Fig. 4: total energy consumption and end-to-end training time to reach
a common target accuracy per dataset (MNIST 95%, CIFAR-10 75%, EuroSAT
80% in the paper; the simulated datasets use the same targets).

    PYTHONPATH=src python -m benchmarks.energy_time [--quick]
"""
from __future__ import annotations

import argparse

from benchmarks.common import (BenchSetup, DATASETS, TARGET_ACC, print_csv,
                               run_baseline, run_crosatfl, save_rows)
from repro.fl.baselines import BASELINES
from repro.obs import get_logger

log = get_logger("benchmarks.energy_time")


def _to_target(hist, target):
    """First round reaching target (None if never)."""
    for h in hist:
        if h["acc"] >= target:
            return h
    return None


def run(datasets, rounds, n_train, n_clients, local_epochs, scale=1.0):
    rows = []
    for dataset in datasets:
        target = TARGET_ACC[dataset] * scale
        setup = BenchSetup(dataset=dataset, iid=True, rounds=rounds,
                           n_train=n_train, n_clients=n_clients,
                           local_epochs=local_epochs)
        for method in ["CroSatFL"] + list(BASELINES):
            if method == "CroSatFL":
                _, ledger, hist = run_crosatfl(setup)
            else:
                _, ledger, hist = run_baseline(method, setup)
            hit = _to_target(hist, target)
            at = hit if hit is not None else hist[-1]
            rows.append({
                "method": method, "dataset": dataset, "target": target,
                "reached": hit is not None,
                "rounds_to_target": at["round"] + 1,
                "total_energy_kj": at["tx_energy_kj"] + at["train_energy_kj"],
                "tx_energy_kj": at["tx_energy_kj"],
                "train_energy_kj": at["train_energy_kj"],
                "train_time_h": at["wall_clock_h"] + at["waiting_h"],
                "final_acc": hist[-1]["acc"],
            })
            log.info(f"{method:10s} {dataset}: reached={rows[-1]['reached']} "
                     f"E={rows[-1]['total_energy_kj']:.2f}kJ "
                     f"T={rows[-1]['train_time_h']:.1f}h")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        rows = run(list(DATASETS)[:1], rounds=4, n_train=800, n_clients=10,
                   local_epochs=1, scale=0.5)
    else:
        rows = run(list(DATASETS), rounds=15, n_train=2400, n_clients=20,
                   local_epochs=3, scale=1.0)
    save_rows("energy_time", rows)
    print_csv(rows)


if __name__ == "__main__":
    main()
