"""Roofline table (deliverable g): reads results/dryrun.jsonl produced by
``python -m repro.launch.dryrun --all --both-meshes`` and renders the
per-(arch x shape x mesh) three-term roofline with dominant bottleneck and
one-line recommendations.

    PYTHONPATH=src python -m benchmarks.roofline [--json results/dryrun.jsonl]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.obs import get_logger

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

log = get_logger("benchmarks.roofline")

RECOMMEND = {
    "compute": "compute-bound: raise MXU utilization (bigger block shapes, "
               "bf16 dots, fewer replicated-compute regions)",
    "memory": "memory-bound: fuse elementwise chains, cut remat recompute, "
              "keep activations bf16, widen per-step batch per device",
    "collective": "collective-bound: reduce TP activation all-reduces "
                  "(pure-DP/FSDP for small-d archs, sequence-parallel "
                  "norms), overlap grad reduce with backward",
}


def load(path):
    rows = []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("status") == "ok":
                rows.append(r)
    # dedupe, keep last per (arch, shape, mesh)
    uniq = {}
    for r in rows:
        uniq[(r["arch"], r["shape"], r["mesh"])] = r
    return list(uniq.values())


def render(rows):
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} "
           f"{'t_comp(s)':>10s} {'t_mem(s)':>10s} {'t_coll(s)':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'frac':>6s}")
    log.raw(hdr)
    log.raw("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        log.raw(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
                f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
                f"{r['t_collective_s']:10.4f} {r['dominant']:>10s} "
                f"{r['useful_ratio']:7.3f} {r['roofline_fraction']:6.3f}")
    log.raw("")
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:3]
    log.info("Hillclimb candidates (worst roofline fraction):")
    for r in worst:
        log.info(f"  {r['arch']} x {r['shape']} [{r['mesh']}] "
                 f"frac={r['roofline_fraction']:.4f} dom={r['dominant']}: "
                 f"{RECOMMEND[r['dominant']]}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(RESULTS, "dryrun.jsonl"))
    args = ap.parse_args(argv)
    if not os.path.exists(args.json):
        log.warn(f"no dry-run results at {args.json}; run "
                 f"`python -m repro.launch.dryrun --all --both-meshes` first")
        return 1
    rows = load(args.json)
    render(rows)
    return 0


if __name__ == "__main__":
    main()
