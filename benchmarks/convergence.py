"""Figs. 2-3: convergence of CroSatFL vs the five baselines, IID and
non-IID (Dirichlet alpha=0.5), on the three simulated datasets.

    PYTHONPATH=src python -m benchmarks.convergence [--quick] [--datasets ...]

Writes results/convergence.jsonl with per-round accuracy per method.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (BenchSetup, DATASETS, print_csv, run_baseline,
                               run_crosatfl, save_rows)
from repro.fl.baselines import BASELINES
from repro.obs import get_logger

log = get_logger("benchmarks.convergence")


def run(datasets, iid_modes, rounds, n_train, n_clients, local_epochs):
    rows = []
    for dataset in datasets:
        for iid in iid_modes:
            setup = BenchSetup(dataset=dataset, iid=iid, rounds=rounds,
                               n_train=n_train, n_clients=n_clients,
                               local_epochs=local_epochs)
            _, ledger, hist = run_crosatfl(setup)
            for h in hist:
                rows.append({"method": "CroSatFL", "dataset": dataset,
                             "iid": iid, "round": h["round"],
                             "acc": h["acc"], "loss": h["loss"]})
            log.info(f"CroSatFL {dataset} iid={iid}: "
                     f"final acc {hist[-1]['acc']:.3f}")
            for name in BASELINES:
                _, _, bh = run_baseline(name, setup)
                for h in bh:
                    rows.append({"method": name, "dataset": dataset,
                                 "iid": iid, "round": h["round"],
                                 "acc": h["acc"], "loss": h["loss"]})
                log.info(f"{name} {dataset} iid={iid}: "
                         f"final acc {bh[-1]['acc']:.3f}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--datasets", nargs="*", default=list(DATASETS))
    args = ap.parse_args(argv)
    if args.quick:
        rows = run(args.datasets[:1], [True], rounds=4, n_train=800,
                   n_clients=10, local_epochs=1)
    else:
        rows = run(args.datasets, [True, False], rounds=15, n_train=2400,
                   n_clients=20, local_epochs=3)
    save_rows("convergence", rows)
    # summary CSV: final accuracy per (method, dataset, iid)
    finals = {}
    for r in rows:
        finals[(r["method"], r["dataset"], r["iid"])] = r
    print_csv(list(finals.values()))


if __name__ == "__main__":
    main()
