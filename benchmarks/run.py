"""Benchmark aggregator: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default is the QUICK profile (CPU-container-sized: fewer rounds/clients);
``--full`` runs the paper-scale protocol (40 clients, 40 edge rounds,
10 local epochs) — hours on this CPU, intended for real hardware.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[])
    args = ap.parse_args(argv)
    quick = [] if args.full else ["--quick"]

    from benchmarks import (ablations, comm_breakdown, convergence,
                            energy_time, hardware_mix, roofline)

    suite = [
        ("convergence (Figs. 2-3)", convergence.main, quick),
        ("energy_time (Fig. 4)", energy_time.main, quick),
        ("comm_breakdown (Table II)", comm_breakdown.main, quick),
        ("hardware_mix (Fig. 5)", hardware_mix.main, quick),
        ("ablations (beyond-paper)", ablations.main, quick),
        ("roofline baseline (EXPERIMENTS §Roofline)", roofline.main, []),
    ]
    import os
    opt = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_opt.jsonl")
    if os.path.exists(opt):
        suite.append(("roofline optimized (EXPERIMENTS §Perf)",
                      roofline.main, ["--json", opt]))
    failures = 0
    for name, fn, fargs in suite:
        if any(s in name for s in args.skip):
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            fn(fargs)
        except Exception as e:  # keep the suite running
            failures += 1
            print(f"FAILED {name}: {type(e).__name__}: {e}")
        print(f"--- {name} done in {time.time() - t0:.0f}s ---")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
