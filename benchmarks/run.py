"""Benchmark aggregator: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default is the QUICK profile (CPU-container-sized: fewer rounds/clients);
``--full`` runs the paper-scale protocol (40 clients, 40 edge rounds,
10 local epochs) — hours on this CPU, intended for real hardware.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.obs import get_logger

log = get_logger("benchmarks.run")


def smoke(measured_cost: bool = False, trace: bool = False,
          only: list | None = None) -> int:
    """1-round run of all six algorithms PLUS the scenario-zoo presets
    (semi-sync/async pacing, gossip-only, per-cluster codec map) on a tiny
    setup through the shared RoundEngine — catches engine regressions in
    the benchmark entry points (CI runs this; it is much cheaper than any
    --quick profile). Writes every ledger to results/smoke_ledgers.json so
    CI can upload them as a diffable artifact. ``measured_cost``: resolve
    c_flop from the compiled-HLO estimate for the gemma3-1b/train_4k cell
    instead of the 5e7 default. ``trace``: attach a ``TracingObserver``
    per method, bit-reconcile each trace against its ledger, and write
    the per-method JSONL traces + ``trace.json`` + the paper-style report
    table under results/obs/.
    """
    import dataclasses
    import json
    import os

    import numpy as np

    from benchmarks.common import (RESULTS, BenchSetup, run_baseline,
                                   run_crosatfl, run_crosatfl_lm,
                                   run_scenario)
    from repro.fl.baselines import BASELINES
    from repro.fl.engine import SCENARIO_NAMES

    from repro.faults import corruption_schedule, smoke_schedule

    # executor-layer cells (repro.fl.exec): CroSatFL through the batched
    # fleet path on both model families — image CNN and the reduced
    # repro.models transformer; plus the fault-injection cell (CroSatFL
    # under the repro.faults smoke campaign — recovery paths in the
    # benchmark entry point, not just the chaos harness) and the robust
    # cell (median aggregation + quorum gate under seeded silent
    # corruption — the Byzantine-defense path in the benchmark entry
    # point, not just the chaos harness)
    exec_cells = {
        "CroSatFL-ExecBatched":
            lambda obs: run_crosatfl(setup, eval_every=False, observer=obs,
                                     executor="batched"),
        "CroSatFL-ExecBatchedLM":
            lambda obs: run_crosatfl_lm(setup, eval_every=False,
                                        observer=obs, executor="batched"),
        "CroSatFL-Faulted":
            lambda obs: run_crosatfl(setup, eval_every=False, observer=obs,
                                     faults=smoke_schedule(
                                         seed=setup.seed,
                                         n_clusters=setup.k_max,
                                         n_clients=setup.n_clients)),
        "CroSatFL-Robust":
            lambda obs: run_crosatfl(setup, eval_every=False, observer=obs,
                                     aggregator="median", quorum=0.6,
                                     faults=corruption_schedule(
                                         seed=setup.seed,
                                         n_clusters=setup.k_max,
                                         n_clients=setup.n_clients)),
    }

    setup = BenchSetup(dataset="eurosat-sim", n_clients=8, n_train=400,
                       n_test=100, rounds=1, local_epochs=1, k_max=4)
    if measured_cost:
        setup = dataclasses.replace(
            setup, c_flop="measured:gemma3-1b/train_4k")
    obs_dir = os.path.join(RESULTS, "obs")
    if trace:
        os.makedirs(obs_dir, exist_ok=True)
    failures = 0
    methods = (["CroSatFL"] + list(BASELINES) + list(SCENARIO_NAMES)
               + list(exec_cells))
    if only:
        unknown = sorted(set(only) - set(methods))
        if unknown:
            log.warn(f"--only: unknown methods {unknown} "
                     f"(choose from {methods})")
            return 1
        methods = [m for m in methods if m in set(only)]
    ledgers = {}
    trace_paths = []
    for method in methods:
        try:
            obs = None
            if trace:
                from repro.obs import TracingObserver
                obs = TracingObserver(
                    os.path.join(obs_dir, f"{method}.jsonl"))
            if method == "CroSatFL":
                _, ledger, _ = run_crosatfl(setup, eval_every=False,
                                            observer=obs)
            elif method in BASELINES:
                _, ledger, _ = run_baseline(method, setup,
                                            eval_every=False, observer=obs)
            elif method in exec_cells:
                _, ledger, _ = exec_cells[method](obs)
            else:
                _, ledger, _ = run_scenario(method, setup,
                                            eval_every=False, observer=obs)
            ledgers[method] = dataclasses.asdict(ledger)
            row = ledger.row()
            # gossip-only sessions never touch the GS — that IS the point
            gs_ok = (row["gs_comm"] == 0 and row["intra_lisl"] > 0
                     if method == "CroSatFL-Gossip" else row["gs_comm"] > 0)
            ok = (gs_ok and ledger.total_energy_j > 0 and
                  all(np.isfinite(v) and v >= 0 for k, v in row.items()
                      if k.endswith(("_kj", "_h"))))
            if obs is not None:
                rec = obs.reconcile(ledger)
                ok = ok and rec["exact"]
                obs.tracer.to_chrome_trace(
                    os.path.join(obs_dir, f"{method}.trace.json"))
                trace_paths.append(obs.tracer.jsonl_path)
            log.info(f"{'ok ' if ok else 'BAD'} {method:20s} "
                     f"gs={row['gs_comm']:3d} intra={row['intra_lisl']:4d} "
                     f"txE={row['tx_energy_kj']:.3g}kJ "
                     f"trainE={row['train_energy_kj']:.3g}kJ")
            failures += 0 if ok else 1
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            failures += 1
            log.warn(f"FAILED {method}: {type(e).__name__}: {e}")
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "smoke_ledgers.json")
    with open(out, "w") as f:
        json.dump(ledgers, f, indent=1, sort_keys=True)
    log.info(f"wrote {out}")
    if trace_paths:
        from repro.obs.report import render
        table = render(trace_paths)
        report_path = os.path.join(obs_dir, "report.txt")
        with open(report_path, "w") as f:
            f.write(table + "\n")
        log.raw("")
        log.raw(table)
        log.info(f"wrote {report_path}")
    log.raw("")
    log.info(f"smoke: {len(methods) - failures}/{len(methods)} "
             "algorithms ok")
    return 1 if failures else 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="1-round engine smoke of all six algorithms")
    ap.add_argument("--measured-cost", action="store_true",
                    help="with --smoke: c_flop from HLO dry-run estimates")
    ap.add_argument("--trace", action="store_true",
                    help="with --smoke: per-method TracingObserver; "
                         "traces + report under results/obs/")
    ap.add_argument("--only", nargs="*", default=None,
                    help="with --smoke: run only these methods (e.g. "
                         "--only CroSatFL-EventAsync for CI's "
                         "event-sim-smoke job)")
    ap.add_argument("--skip", nargs="*", default=[])
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke(measured_cost=args.measured_cost, trace=args.trace,
                     only=args.only)
    quick = [] if args.full else ["--quick"]

    from benchmarks import (ablations, comm_breakdown, convergence,
                            energy_time, hardware_mix, roofline)

    suite = [
        ("convergence (Figs. 2-3)", convergence.main, quick),
        ("energy_time (Fig. 4)", energy_time.main, quick),
        ("comm_breakdown (Table II)", comm_breakdown.main, quick),
        ("hardware_mix (Fig. 5)", hardware_mix.main, quick),
        ("ablations (beyond-paper)", ablations.main, quick),
        ("roofline baseline (EXPERIMENTS §Roofline)", roofline.main, []),
    ]
    import os
    opt = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_opt.jsonl")
    if os.path.exists(opt):
        suite.append(("roofline optimized (EXPERIMENTS §Perf)",
                      roofline.main, ["--json", opt]))
    failures = 0
    for name, fn, fargs in suite:
        if any(s in name for s in args.skip):
            continue
        log.raw(f"\n=== {name} ===")
        t0 = time.time()
        try:
            fn(fargs)
        except Exception as e:  # keep the suite running
            failures += 1
            log.warn(f"FAILED {name}: {type(e).__name__}: {e}")
        log.raw(f"--- {name} done in {time.time() - t0:.0f}s ---")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
