"""Shared benchmark scaffolding: the paper's experimental setup scaled to
the CPU container (same protocol structure, smaller models/data), with a
``--full`` flag for paper-scale runs on real hardware.

All benchmarks print CSV to stdout and write under ``results/``.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

import numpy as np

from repro.constellation import ConstellationEnv
from repro.core.session import Session, SessionConfig
from repro.core.starmask import StarMaskParams
from repro.data.synth import dirichlet_partition, iid_partition, make_dataset
from repro.fl.baselines import BASELINES, BaselineConfig
from repro.fl.client import ImageFLModel
from repro.obs import get_logger

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

log = get_logger("benchmarks")

DATASETS = ("mnist-sim", "cifar10-sim", "eurosat-sim")
TARGET_ACC = {"mnist-sim": 0.95, "cifar10-sim": 0.75, "eurosat-sim": 0.80}


@dataclass
class BenchSetup:
    dataset: str
    iid: bool = True
    n_clients: int = 40
    n_train: int = 4000
    n_test: int = 800
    rounds: int = 40
    local_epochs: int = 10
    k_max: int = 12
    k_nbr: int = 2
    seed: int = 0
    gpu_fraction: float = 0.5
    # FLOPs/sample for the energy model: a float, or "measured:<arch>/<shape>"
    # to resolve from compiled-HLO dry-run estimates (fl/engine/costs.py)
    c_flop: object = 5e7

    def build(self):
        ds = make_dataset(self.dataset, n=self.n_train, seed=self.seed)
        test = make_dataset(self.dataset, n=self.n_test, seed=self.seed + 99)
        if self.iid:
            parts = iid_partition(len(ds.y), self.n_clients, self.seed)
        else:
            parts = dirichlet_partition(ds.y, self.n_clients, alpha=0.5,
                                        seed=self.seed)
        env = ConstellationEnv(
            n_clients=self.n_clients,
            n_samples=np.array([len(p) for p in parts], float),
            gpu_fraction=self.gpu_fraction, seed=self.seed)
        model = ImageFLModel(ds, parts, test)
        return env, model

    def session_config(self, model) -> SessionConfig:
        return SessionConfig(
            edge_rounds=self.rounds, local_epochs=self.local_epochs,
            k_nbr=self.k_nbr, c_flop=self.c_flop,
            model_bits=model.model_bits(),
            seed=self.seed, starmask=StarMaskParams(k_max=self.k_max,
                                                    m_min=2))

    def baseline_config(self, model) -> BaselineConfig:
        return BaselineConfig(
            rounds=self.rounds, local_epochs=self.local_epochs,
            c_flop=self.c_flop, model_bits=model.model_bits(),
            seed=self.seed)


def run_crosatfl(setup: BenchSetup, eval_every: bool = True,
                 observer=None, executor=None, faults=None,
                 aggregator=None, quorum=None):
    """``executor`` overrides the round execution mode (repro.fl.exec:
    "sequential" / "batched" / "sharded"); None keeps the default.
    ``faults`` attaches a repro.faults schedule/injector (None = the
    fault-free golden path). ``aggregator`` picks a merge-time robust
    aggregator (repro.fl.robust; None = bit-exact FedAvg default) and
    ``quorum`` a minimum valid-participation fraction per cluster."""
    import dataclasses
    env, model = setup.build()
    cfg = setup.session_config(model)
    if executor is not None:
        cfg = dataclasses.replace(cfg, executor=executor)
    if aggregator is not None:
        cfg = dataclasses.replace(cfg, aggregator=aggregator)
    if quorum is not None:
        cfg = dataclasses.replace(cfg, quorum=quorum)
    sess = Session(cfg, env, model, observer=observer, faults=faults)
    eval_fn = (lambda p, r: model.evaluate(p)) if eval_every else None
    return sess.run(eval_fn=eval_fn)


def run_crosatfl_lm(setup: BenchSetup, eval_every: bool = True,
                    observer=None, executor="batched"):
    """CroSatFL over the reduced-transformer LM adapter
    (repro.fl.models_lm.TinyLMFLModel) — the executor layer is
    model-agnostic, so the same smoke that drives ImageFLModel drives a
    repro.models transformer through the batched fleet path."""
    from repro.fl.engine import EngineConfig, make_crosatfl
    from repro.fl.models_lm import TinyLMFLModel

    model = TinyLMFLModel(setup.n_clients, seed=setup.seed)
    env = ConstellationEnv(n_clients=setup.n_clients,
                           n_samples=model.sizes.astype(float),
                           gpu_fraction=setup.gpu_fraction, seed=setup.seed)
    cfg = EngineConfig(rounds=setup.rounds, local_epochs=setup.local_epochs,
                       c_flop=setup.c_flop, model_bits=model.model_bits(),
                       seed=setup.seed, executor=executor)
    eng = make_crosatfl(cfg, env, model, k_nbr=setup.k_nbr,
                        starmask=StarMaskParams(k_max=setup.k_max, m_min=2),
                        name="CroSatFL-LM", observer=observer)
    eval_fn = (lambda p, r: model.evaluate(p)) if eval_every else None
    return eng.run(eval_fn=eval_fn)


def run_baseline(name: str, setup: BenchSetup, eval_every: bool = True,
                 observer=None):
    env, model = setup.build()
    eng = BASELINES[name](setup.baseline_config(model), env, model,
                          observer=observer)
    eval_fn = (lambda p, r: model.evaluate(p)) if eval_every else None
    return eng.run(eval_fn=eval_fn)


def run_scenario(name: str, setup: BenchSetup, eval_every: bool = True,
                 observer=None, **kw):
    """Scenario-zoo presets (fl/engine/presets.SCENARIO_NAMES): CroSatFL's
    quadruple with one policy swapped (pacing / gossip-only / codec map)."""
    from repro.fl.engine import make_scenario
    env, model = setup.build()
    scfg = setup.session_config(model)
    eng = make_scenario(name, scfg.engine_config(), env, model,
                        k_nbr=scfg.k_nbr, starmask=scfg.starmask,
                        observer=observer, **kw)
    eval_fn = (lambda p, r: model.evaluate(p)) if eval_every else None
    return eng.run(eval_fn=eval_fn)


def save_rows(name: str, rows: list[dict]):
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.jsonl")
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r, default=float) + "\n")
    return path


def print_csv(rows: list[dict]):
    if not rows:
        return
    keys = list(rows[0].keys())
    log.raw(",".join(keys))
    for r in rows:
        log.raw(",".join(f"{r.get(k, '')}"
                         if not isinstance(r.get(k), float)
                         else f"{r[k]:.6g}" for k in keys))
