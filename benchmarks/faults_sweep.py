"""Table-II-style fault sensitivity sweep: accuracy + overhead vs rate.

    PYTHONPATH=src python -m benchmarks.faults_sweep --smoke

Sweeps CroSatFL over (a) Poisson outage/crash rates and (b)
Gilbert-Elliott burst intensities (``p_g2b``), each against the
zero-rate clean baseline, and reports per-cell:

* final accuracy (graceful-degradation curve vs fault rate),
* energy and latency **overhead** relative to the clean run (retry
  joules and backoff seconds are real costs — DESIGN.md §13),
* retry / dropped-transfer counts from the fault state.

The sweep runs the default bit-parity FedAvg path — the point is the
cost of *recovering*, not of defending; the silent-corruption defense
curve lives in ``repro.faults.chaos``. Rows land in
``results/BENCH_faults.json`` and print as CSV.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import RESULTS, BenchSetup, print_csv, run_crosatfl
from repro.faults import FaultSchedule
from repro.obs import get_logger

log = get_logger("benchmarks.faults")

# (label, expected events PER SESSION) — the sweep measures the clean
# session's sim duration first, then scales each Poisson rate so the
# same cell means the same fault pressure at smoke scale (sim seconds)
# and paper scale (sim hours)
POISSON_CELLS = (
    ("poisson-0x", dict(outages=0.0, crashes=0.0)),
    ("poisson-1x", dict(outages=4.0, crashes=1.0)),
    ("poisson-3x", dict(outages=12.0, crashes=3.0)),
)
GE_CELLS = (("ge-calm", 0.05), ("ge-bursty", 0.25))


def _schedule(label: str, horizon_s: float, seed: int, n_clusters: int,
              n_clients: int):
    per_h = 3600.0 / horizon_s            # 1 event/session -> rate/hour
    for name, kw in POISSON_CELLS:
        if name == label:
            return FaultSchedule.poisson(
                horizon_s, seed=seed, n_clusters=n_clusters,
                n_clients=n_clients,
                outage_rate_per_h=kw["outages"] * per_h,
                mean_outage_s=horizon_s / 20.0,
                crash_rate_per_h=kw["crashes"] * per_h,
                mean_down_s=horizon_s / 5.0)
    for name, p_g2b in GE_CELLS:
        if name == label:
            # ~40 burst-chain steps across the session regardless of scale
            return FaultSchedule.gilbert_elliott(
                horizon_s, seed=seed, p_g2b=p_g2b, p_b2g=0.5,
                step_s=horizon_s / 40.0)
    raise KeyError(label)


def run_sweep(setup: BenchSetup, out: str = "BENCH_faults") -> list[dict]:
    from repro.obs import TracingObserver

    labels = [n for n, _ in POISSON_CELLS] + [n for n, _ in GE_CELLS]
    # clean pre-run fixes the session's sim horizon so every fault cell
    # lands its events *inside* the session, whatever the setup scale
    _, led0, _ = run_crosatfl(setup, eval_every=False)
    horizon = float(led0.wall_clock_s)
    log.info(f"clean session horizon: {horizon:.3g} sim s")
    rows, base = [], None
    for label in labels:
        sch = _schedule(label, horizon, setup.seed, setup.k_max,
                        setup.n_clients)
        obs = TracingObserver()
        _, ledger, hist = run_crosatfl(setup, eval_every=True,
                                       observer=obs, faults=sch)
        acc = float(hist[-1]["acc"]) if hist else float("nan")
        row = {"cell": label, "acc": acc,
               "energy_j": float(ledger.total_energy_j),
               "latency_s": float(ledger.wall_clock_s),
               "retries": int(obs.metrics.total("recoveries",
                                                action="retry")),
               "drops": int(obs.metrics.total("recoveries",
                                              action="drop"))}
        if label == "poisson-0x":
            base = row
        # overhead relative to the clean zero-rate cell (first row)
        row["energy_overhead"] = row["energy_j"] / base["energy_j"] - 1.0
        row["latency_overhead"] = (row["latency_s"] / base["latency_s"]
                                   - 1.0)
        log.info(f"{label:12s} acc={acc:.3f} "
                 f"E+{row['energy_overhead'] * 100:.1f}% "
                 f"T+{row['latency_overhead'] * 100:.1f}% "
                 f"retries={row['retries']} drops={row['drops']}")
        rows.append(row)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{out}.json")
    with open(path, "w") as f:
        json.dump({"setup": {"dataset": setup.dataset,
                             "n_clients": setup.n_clients,
                             "rounds": setup.rounds, "seed": setup.seed},
                   "rows": rows}, f, indent=1, sort_keys=True)
    log.info(f"wrote {path}")
    print_csv(rows)
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fault-rate sensitivity sweep (accuracy + overhead)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: tiny setup, 3 rounds")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        setup = BenchSetup(dataset="eurosat-sim", n_clients=8, n_train=400,
                           n_test=100, rounds=args.rounds or 3,
                           local_epochs=1, k_max=4, seed=args.seed)
    else:
        setup = BenchSetup(dataset="eurosat-sim", n_clients=40,
                           rounds=args.rounds or 40, seed=args.seed)
    rows = run_sweep(setup)
    # contract: every cell completes with a finite accuracy, and the
    # clean cell pays zero retry overhead
    ok = (all(r["acc"] == r["acc"] for r in rows)
          and rows[0]["retries"] == 0 and rows[0]["drops"] == 0)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
