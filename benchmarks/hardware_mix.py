"""Fig. 5: single-edge-round computation energy and time under three
hardware compositions (All-CPUs / Half-Mixed / All-GPUs), CroSatFL
(Skip-One on) vs FedOrbit (full participation).

    PYTHONPATH=src python -m benchmarks.hardware_mix [--quick]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import BenchSetup, print_csv, save_rows
from repro.core.energy import e_train, t_train
from repro.core import skipone
from repro.obs import get_logger

log = get_logger("benchmarks.hardware_mix")


def one_round(setup: BenchSetup, skip_one: bool, jitter):
    """Analytic single-round cost on the sampled hardware profiles
    (matches the session controller's accounting)."""
    env, model = setup.build()
    alpha = np.array([p.alpha for p in env.profiles])
    cfg = setup.session_config(model)
    tt = t_train(env.n_samples, cfg.c_flop, alpha, cfg.local_epochs)
    ee = e_train(env.n_samples, cfg.c_flop, env.profiles, cfg.local_epochs)
    tt = tt * jitter
    # 9-ish clusters of ~n/9
    order = np.argsort(tt)
    K = max(1, setup.n_clients // 5)
    clusters = [order[i::K] for i in range(K)]
    tot_e, barrier = 0.0, 0.0
    for c in clusters:
        if skip_one:
            st = skipone.SkipOneState.init(len(c))
            mask, _ = skipone.select(tt[c], ee[c], np.zeros(len(c)), st,
                                     skipone.SkipOneParams(), 0)
        else:
            mask = np.ones(len(c), bool)
        tot_e += ee[c][mask].sum()
        barrier = max(barrier, tt[c][mask].max() if mask.any() else 0.0)
    return tot_e, barrier


def run(n_clients, n_train):
    rows = []
    rng = np.random.default_rng(0)
    for name, frac in (("All-CPUs", 0.0), ("Half-Mixed", 0.5),
                       ("All-GPUs", 1.0)):
        setup = BenchSetup(dataset="eurosat-sim", n_clients=n_clients,
                           n_train=n_train, gpu_fraction=frac)
        jitter = rng.lognormal(0, 0.25, n_clients)
        e_skip, t_skip = one_round(setup, skip_one=True, jitter=jitter)
        e_full, t_full = one_round(setup, skip_one=False, jitter=jitter)
        rows.append({"composition": name,
                     "crosatfl_energy_kj": e_skip / 1e3,
                     "crosatfl_time_s": t_skip,
                     "fedorbit_energy_kj": e_full * 0.5 / 1e3,  # minifloat
                     "fedorbit_time_s": t_full})
        log.info(f"{name:10s} CroSatFL E={e_skip/1e3:7.2f}kJ "
                 f"T={t_skip:7.1f}s | "
                 f"FedOrbit E={e_full*0.5/1e3:7.2f}kJ T={t_full:7.1f}s")
    # paper's qualitative claims
    assert rows[2]["crosatfl_energy_kj"] < rows[0]["crosatfl_energy_kj"], \
        "GPU fleet should be cheaper per round"
    assert all(r["crosatfl_time_s"] <= r["fedorbit_time_s"] + 1e-9
               for r in rows), "Skip-One must not lengthen the round"
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rows = run(n_clients=10 if args.quick else 40,
               n_train=800 if args.quick else 4000)
    save_rows("hardware_mix", rows)
    print_csv(rows)


if __name__ == "__main__":
    main()
