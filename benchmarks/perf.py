"""Round-engine performance harness over the executor layer: sequential
vs batched vs sharded execution, plus batched + Pallas cross-agg mixing
(DESIGN.md §9, §12).

    PYTHONPATH=src python -m benchmarks.perf [--smoke] [--sizes a,b]
        [--out PATH] [--trace]

Per constellation size, builds ONE (env, model) setup and times a full
``RoundEngine.run`` per execution mode (after a 2-round warmup run that
pays all jit compiles), reporting rounds/sec and local-SGD steps/sec —
steps counted exactly via an ``EngineObserver`` that records every
selected participant, so all paths are compared on identical realized
work (same seed -> same Skip-One draws). The sharded mode uses whatever
devices the process sees — run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (CI's perf-smoke
does) for a real multi-device pod mesh; on one device it degrades to the
batched path plus placement overhead.

XLA compile events (count + seconds per mode, via
``repro.obs.jaxprof.CompileWatcher``) are always captured and land in
the report — batched-vs-sequential compile overhead is part of the
story. ``--trace`` additionally wraps each mode's first timed run in a
``jax.profiler`` capture (TensorBoard-loadable, under
results/jaxprof/).

Writes ``BENCH_round_engine.json`` at the repo root (NOT results/, which
is gitignored): the file seeds the repo's perf trajectory, is committed,
and CI's ``perf-smoke`` job uploads its ``--smoke`` variant as a diffable
artifact next to the smoke ledgers. The per-client data is deliberately
small (8x8 single-channel images, 10 samples/client): the batched path's
win is per-call dispatch + per-op thunk overhead + unstack/restack +
host->device traffic, which is exactly the regime a dense-constellation
simulation at fixed per-satellite data lives in.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.obs import get_logger
from repro.obs.jaxprof import CompileWatcher
from repro.obs.jaxprof import trace as profiler_trace

ROOT = os.path.join(os.path.dirname(__file__), "..")
DEFAULT_OUT = os.path.join(ROOT, "BENCH_round_engine.json")
TRACE_DIR = os.path.join(ROOT, "results", "jaxprof")

log = get_logger("benchmarks.perf")

# constellation sizes: the 40-client/8-cluster cell is the pinned
# acceptance config; 16/4 and 96/16 bracket it
SIZES = {
    "fleet16": dict(n_clients=16, k_max=4, rounds=20),
    "fleet40": dict(n_clients=40, k_max=8, rounds=20),
    "fleet96": dict(n_clients=96, k_max=16, rounds=10),
}
SMOKE_SIZES = {"fleet16": dict(n_clients=16, k_max=4, rounds=8)}

MODES = ("sequential", "batched", "sharded", "batched+pallas-mix")

# which Executor each benchmark mode selects (pallas-mix swaps the
# mixing backend, not the executor)
MODE_EXECUTOR = {"sequential": "sequential", "batched": "batched",
                 "sharded": "sharded", "batched+pallas-mix": "batched"}

HW, CHANNELS, WIDTH, PER_CLIENT, EPOCHS = 8, 1, 4, 10, 1


def _make_counter():
    """Observer that counts selected participants (exact steps/sec) —
    executor-agnostic, unlike the model proxy it replaced, which only saw
    the entry points it knew to intercept."""
    from repro.obs.observer import EngineObserver

    class _CountingObserver(EngineObserver):
        def __init__(self):
            self.participants = 0

        def select(self, round_idx, kc, sel):
            self.participants += len(sel.participants)

    return _CountingObserver()


def build_setup(size_cfg: dict, seed: int = 0):
    import numpy as np

    from repro.constellation import ConstellationEnv
    from repro.data.synth import SynthImageDataset, iid_partition
    from repro.fl.client import ImageFLModel

    n_clients = size_cfg["n_clients"]
    ds = SynthImageDataset.make(name="bench-sim", n=PER_CLIENT * n_clients,
                                hw=HW, c=CHANNELS, snr=2.0, n_classes=10,
                                seed=seed)
    test = SynthImageDataset.make(name="bench-sim", n=100, hw=HW, c=CHANNELS,
                                  snr=2.0, n_classes=10, seed=seed + 99)
    parts = iid_partition(len(ds.y), n_clients, seed)
    env = ConstellationEnv(
        n_clients=n_clients,
        n_samples=np.array([len(p) for p in parts], float), seed=seed)
    model = ImageFLModel(ds, parts, test, width=WIDTH)
    return env, model


def make_engine(mode: str, env, model, size_cfg: dict, observer=None):
    from repro.core.starmask import StarMaskParams
    from repro.fl.engine import EngineConfig, make_crosatfl

    cfg = EngineConfig(rounds=size_cfg["rounds"], local_epochs=EPOCHS,
                       model_bits=model.model_bits(), seed=0,
                       executor=MODE_EXECUTOR[mode])
    return make_crosatfl(
        cfg, env, model,
        starmask=StarMaskParams(k_max=size_cfg["k_max"], m_min=2),
        mixing_backend="pallas" if mode.endswith("pallas-mix") else None,
        name=f"CroSatFL[{mode}]", observer=observer)


def time_mode(mode: str, env, model, size_cfg: dict,
              repeats: int = 3, watcher: CompileWatcher = None,
              trace_dir: str = None) -> dict:
    """Best-of-``repeats`` full runs (after a compile-paying warmup run):
    the container's CPU shares are bursty, and best-of is the standard
    way to report the machine's actual capability per mode.

    ``watcher`` attributes the warmup's XLA compile events to this mode;
    ``trace_dir`` wraps the first timed run in a jax profiler capture.
    """
    import contextlib

    import jax

    counter = _make_counter()
    eng = make_engine(mode, env, model, size_cfg, observer=counter)
    label = f"warmup:{mode}"
    with (watcher.track(label) if watcher is not None
          else contextlib.nullcontext()):
        eng.run(rounds=2)                    # warmup: pay every jit compile
    wall, steps = float("inf"), 0
    for rep in range(repeats):
        counter.participants = 0
        prof = (profiler_trace(os.path.join(trace_dir, mode))
                if trace_dir is not None and rep == 0
                else contextlib.nullcontext())
        with prof:
            t0 = time.perf_counter()
            w, ledger, _ = eng.run()
            jax.block_until_ready(jax.tree.leaves(w))
            dt = time.perf_counter() - t0
        if dt < wall:
            wall = dt
            steps = (counter.participants * EPOCHS
                     * (model.n_pad // model.batch))
    rounds = size_cfg["rounds"]
    out = {
        "wall_s": round(wall, 4),
        "rounds_per_s": round(rounds / wall, 4),
        "local_steps_per_s": round(steps / wall, 2),
        "n_clusters": eng.last_plan.n_clusters,
        "timing": f"best of {repeats}",
    }
    if watcher is not None:
        slot = watcher.by_label.get(label, {})
        out["compile"] = {"events": slot.get("events", 0),
                          "seconds": round(slot.get("seconds", 0.0), 4)}
    return out


def run(sizes: dict, out_path: str, trace: bool = False) -> int:
    import jax

    report = {
        "harness": "benchmarks/perf.py",
        "protocol": {
            "dataset": f"bench-sim {HW}x{HW}x{CHANNELS}",
            "model": f"small-cnn width={WIDTH}",
            "samples_per_client": PER_CLIENT,
            "local_epochs": EPOCHS,
            "warmup": "one 2-round run per mode before timing",
        },
        "platform": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "devices": len(jax.devices()),
            "cpu_count": os.cpu_count(),
        },
        "sizes": {},
    }
    failures = 0
    with CompileWatcher() as watcher:
        for name, size_cfg in sizes.items():
            env, model = build_setup(size_cfg)
            row: dict = {"config": dict(size_cfg), "modes": {}}
            trace_dir = (os.path.join(TRACE_DIR, name) if trace else None)
            for mode in MODES:
                try:
                    row["modes"][mode] = time_mode(
                        mode, env, model, size_cfg, watcher=watcher,
                        trace_dir=trace_dir)
                    m = row["modes"][mode]
                    log.raw(f"{name:8s} {mode:20s} {m['wall_s']:8.3f}s "
                            f"{m['rounds_per_s']:7.2f} rounds/s "
                            f"{m['local_steps_per_s']:9.1f} steps/s "
                            f"K={m['n_clusters']} "
                            f"compile={m['compile']['seconds']}s")
                except Exception as e:  # noqa: BLE001 — keep sweeping
                    failures += 1
                    log.warn(f"FAILED {name}/{mode}: "
                             f"{type(e).__name__}: {e}")
            seq = row["modes"].get("sequential")
            if seq:
                row["speedup_vs_sequential"] = {
                    mode: round(row["modes"][mode]["rounds_per_s"]
                                / seq["rounds_per_s"], 3)
                    for mode in row["modes"] if mode != "sequential"}
                log.raw(f"{name:8s} speedup: " + "  ".join(
                    f"{k}={v}x"
                    for k, v in row["speedup_vs_sequential"].items()))
            report["sizes"][name] = row
        report["compile_events"] = watcher.summary()
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    log.info(f"wrote {out_path}")
    if trace:
        log.info(f"profiler traces under {TRACE_DIR}")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny single-size profile for CI")
    ap.add_argument("--sizes", default=None,
                    help=f"comma-separated subset of {list(SIZES)}")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--trace", action="store_true",
                    help="jax profiler capture of each mode's first timed "
                         "run (results/jaxprof/)")
    args = ap.parse_args(argv)
    sizes = SMOKE_SIZES if args.smoke else SIZES
    if args.sizes:
        sizes = {k: SIZES[k] for k in args.sizes.split(",")}
    return run(sizes, args.out, trace=args.trace)


if __name__ == "__main__":
    sys.exit(main())
