"""FROZEN pre-refactor implementations of the CroSatFL session loop and
the five baseline loops, kept verbatim (plus the skipped-satellite idle
accounting fix) as the parity reference for the pluggable RoundEngine.

Do NOT refactor this module against src/ — its whole value is that it
does not change. test_engine_parity.py runs these side-by-side with the
engine in the same process and asserts bit-for-bit identical ledgers and
weights (XLA CPU results are only reproducible within one process, so the
weight comparison must be in-process; the host-side ledger is additionally
pinned cross-process in golden_engine.json).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import numpy as np

from repro.core import crossagg, skipone
from repro.core.energy import (GPU, EnergyLedger, e_gs, e_lisl, e_train,
                               t_gs, t_lisl, t_train)
from repro.core.starmask import Instance, cluster as starmask_cluster

RELAY_FALLBACK_M = 3e6


# ---------------------------------------------------------------------------
# Pre-refactor core/session.py (verbatim run() body, module-level helpers)
# ---------------------------------------------------------------------------

def _make_instance(cfg, env):
    n = env.n_clients
    alpha = np.array([p.alpha for p in env.profiles])
    tt = t_train(env.n_samples, cfg.c_flop, alpha, cfg.local_epochs)
    et = e_train(env.n_samples, cfg.c_flop, env.profiles, cfg.local_epochs)
    lisl_e = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            dist = env.lisl_distance(i, j, 0.0)
            lisl_e[i, j] = (e_lisl(cfg.model_bits, env.link_params.lisl_rate,
                                   dist, env.link_params)
                            if np.isfinite(dist) else 1e9)
    return Instance(
        share=env.n_samples / env.n_samples.sum(),
        hw=np.array([p.hw_type for p in env.profiles]),
        t_comp=tt / cfg.local_epochs,
        e_train=et,
        fanout=np.asarray(env.fanout),
        lisl_e=lisl_e,
    )


def _dist(env, i, j, t):
    d = env.lisl_distance(int(i), int(j), t)
    return d if np.isfinite(d) else RELAY_FALLBACK_M


def _hw_penalty(inst):
    frac_gpu = inst.hw.mean()
    rare_gpu = 1.0 - frac_gpu
    return np.where(inst.hw == GPU, rare_gpu, frac_gpu)


def _migrate(env, cluster_ids, from_sat, t_now):
    best, best_fo = cluster_ids[0], -1
    for j in cluster_ids:
        if j == from_sat:
            continue
        if np.isfinite(env.lisl_distance(int(from_sat), int(j), t_now)):
            fo = env.fanout[j]
            if fo > best_fo:
                best, best_fo = j, fo
    return int(best)


def reference_session_run(cfg, env, model,
                          eval_fn: Optional[Callable] = None):
    """Pre-refactor ``Session.run`` (fresh state, fixed idle accounting)."""
    rng = np.random.default_rng(cfg.seed)
    R = cfg.edge_rounds
    key = jax.random.PRNGKey(cfg.seed)

    inst = _make_instance(cfg, env)
    key, sub = jax.random.split(key)
    result = starmask_cluster(inst, cfg.starmask, sub, params=None)
    assert result.feasible, f"StarMask infeasible, K_min={result.k_min}"
    clusters = result.clusters
    K = len(clusters)
    N_k = np.array([env.n_samples[c].sum() for c in clusters], np.float64)

    lp = env.link_params
    d = cfg.model_bits

    ledger = EnergyLedger()
    key, sub = jax.random.split(key)
    w0 = model.init(sub)
    masters = np.array([c[np.argmax(inst.fanout[c])] for c in clusters])
    t_now = 0.0
    for mk in masters:
        wait, dist = env.gs_window_wait(int(mk), t_now)
        ledger.add_wait(wait)
        ledger.add_gs(1, e_gs(d, lp.gs_rate, dist, lp),
                      t_gs(d, lp.gs_rate, dist, lp))
    for c, mk in zip(clusters, masters):
        for i in c:
            if i == mk:
                continue
            dist = _dist(env, int(mk), int(i), t_now)
            ledger.add_intra(1, e_lisl(d, lp.lisl_rate, dist, lp),
                             t_lisl(d, lp.lisl_rate, dist, lp))
    cluster_models = model.stack([w0] * K)
    skip_states = [skipone.SkipOneState.init(len(c)) for c in clusters]

    alpha = np.array([p.alpha for p in env.profiles])
    tt_full = t_train(env.n_samples, cfg.c_flop, alpha, cfg.local_epochs)
    et_full = e_train(env.n_samples, cfg.c_flop, env.profiles,
                      cfg.local_epochs)
    hw_rare = _hw_penalty(inst)

    history: list[dict] = []
    wall = ledger.wall_clock_s
    for r in range(R):
        t_now = wall
        round_barrier = 0.0
        new_models = []
        models_list = model.unstack(cluster_models, K)
        for kc, (c, w_k) in enumerate(zip(clusters, models_list)):
            jitter = rng.lognormal(0.0, 0.25, len(c))
            tt_r = tt_full[c] * jitter
            mask, skip_states[kc] = skipone.select(
                tt_r, et_full[c], hw_rare[c], skip_states[kc],
                cfg.skip_one, r)
            part = c[mask]
            key, sub = jax.random.split(key)
            w_new = model.cluster_round(
                w_k, part, env.n_samples[part], cfg.local_epochs, sub)
            new_models.append(w_new)
            barrier = tt_r[mask].max() if mask.any() else 0.0
            ledger.add_train(float(et_full[c][mask].sum()), float(barrier))
            ledger.add_wait(float((barrier - tt_r[mask]).sum()
                                  + barrier * (~mask).sum()
                                  if mask.any() else 0.0))
            round_barrier = max(round_barrier, float(barrier))
            mk = masters[kc]
            for i in part:
                if i == mk:
                    continue
                dist = env.lisl_distance(int(i), int(mk), t_now)
                if not np.isfinite(dist):
                    mk = _migrate(env, c, i, t_now)
                    masters[kc] = mk
                    dist = _dist(env, int(i), int(mk), t_now)
                ledger.add_intra(1, e_lisl(d, lp.lisl_rate, dist, lp),
                                 t_lisl(d, lp.lisl_rate, dist, lp))

        stacked = model.stack(new_models)

        reach = env.master_reach(masters, t_now)
        groups = crossagg.sample_groups(reach, cfg.k_nbr, rng)
        M = crossagg.mixing_matrix(groups, N_k)
        stacked = crossagg.apply_mixing(M, stacked)
        for kc, g in enumerate(groups):
            for j in g:
                if j == kc:
                    continue
                dist = _dist(env, int(masters[j]), int(masters[kc]), t_now)
                ledger.add_inter(1, e_lisl(d, lp.lisl_rate, dist, lp),
                                 t_lisl(d, lp.lisl_rate, dist, lp))

        cluster_models = stacked
        wall += round_barrier
        ledger.wall_clock_s = wall

        if eval_fn is not None:
            w_glob = crossagg.consolidate(stacked, N_k)
            m = eval_fn(w_glob, r)
            m["round"] = r
            m.update(ledger.row())
            history.append(m)

    w_final = crossagg.consolidate(cluster_models, N_k)
    for mk in masters:
        wait, dist = env.gs_window_wait(int(mk), wall)
        ledger.add_wait(wait)
        ledger.add_gs(1, e_gs(d, lp.gs_rate, dist, lp),
                      t_gs(d, lp.gs_rate, dist, lp))
    return w_final, ledger, history


# ---------------------------------------------------------------------------
# Pre-refactor fl/baselines.py (verbatim class bodies)
# ---------------------------------------------------------------------------

class _Engine:
    name = "base"

    def __init__(self, cfg, env, model):
        self.cfg, self.env, self.model = cfg, env, model
        self.rng = np.random.default_rng(cfg.seed)
        alpha = np.array([p.alpha for p in env.profiles])
        self.tt = t_train(env.n_samples, cfg.c_flop, alpha, cfg.local_epochs)
        self.et = e_train(env.n_samples, cfg.c_flop, env.profiles,
                          cfg.local_epochs)

    def select(self, r):
        return np.arange(self.env.n_clients)

    def communicate(self, participants, ledger, t_now):
        raise NotImplementedError

    def payload_bits(self):
        return self.cfg.model_bits

    def compute_energy(self, participants):
        return float(self.et[participants].sum())

    def run(self, eval_fn=None):
        cfg, env = self.cfg, self.env
        key = jax.random.PRNGKey(cfg.seed)
        ledger = EnergyLedger()
        key, sub = jax.random.split(key)
        w = self.model.init(sub)
        history = []
        wall = 0.0
        for r in range(cfg.rounds):
            part = self.select(r)
            jitter = self.rng.lognormal(0.0, 0.25, len(part))
            tt_r = self.tt[part] * jitter
            key, sub = jax.random.split(key)
            w = self.model.cluster_round(w, part, env.n_samples[part],
                                         cfg.local_epochs, sub)
            barrier = float(tt_r.max())
            ledger.add_train(self.compute_energy(part) * self._arith_scale(),
                             barrier)
            ledger.add_wait(float((barrier - tt_r).sum()))
            wall += barrier
            wall += self.communicate(part, ledger, wall)
            ledger.wall_clock_s = wall
            if eval_fn is not None:
                m = eval_fn(w, r)
                m["round"] = r
                m.update(ledger.row())
                history.append(m)
        return w, ledger, history

    def _arith_scale(self):
        return 1.0


class FedSyn(_Engine):
    name = "FedSyn"

    def communicate(self, part, ledger, t_now):
        env, d = self.env, self.payload_bits()
        lp = env.link_params
        waits = []
        for i in part:
            wait, dist = env.gs_window_wait(int(i), t_now)
            waits.append(wait)
            ledger.add_gs(2, 2 * e_gs(d, lp.gs_rate, dist, lp),
                          2 * t_gs(d, lp.gs_rate, dist, lp))
        wmax = max(waits)
        ledger.add_wait(float(np.sum(wmax - np.asarray(waits))))
        return wmax


class FedLEO(_Engine):
    name = "FedLEO"

    def __init__(self, cfg, env, model):
        super().__init__(cfg, env, model)
        planes = env.constellation.plane_of(env.sat_ids)
        self.groups = [np.flatnonzero(planes == p) for p in np.unique(planes)]
        merged, cur = [], []
        for g in self.groups:
            cur = np.concatenate([cur, g]).astype(int) if len(cur) else g
            if len(cur) >= 3:
                merged.append(cur)
                cur = []
        if len(cur):
            merged.append(cur)
        self.groups = merged

    def communicate(self, part, ledger, t_now):
        env, d = self.env, self.payload_bits()
        lp = env.link_params
        waits = []
        for g in self.groups:
            sink = int(g[np.argmax(env.fanout[g])])
            for i in g:
                if int(i) == sink:
                    continue
                dist = env.lisl_distance(int(i), sink, t_now)
                dist = dist if np.isfinite(dist) else 3e6
                ledger.add_intra(2, 2 * e_lisl(d, lp.lisl_rate, dist, lp),
                                 2 * t_lisl(d, lp.lisl_rate, dist, lp))
            wait, gdist = env.gs_window_wait(sink, t_now)
            waits.append(wait)
            ledger.add_gs(2, 2 * e_gs(d, lp.gs_rate, gdist, lp),
                          2 * t_gs(d, lp.gs_rate, gdist, lp))
        wmax = max(waits)
        ledger.add_wait(float(np.sum(wmax - np.asarray(waits))))
        return wmax


class FELLO(_Engine):
    name = "FELLO"

    def __init__(self, cfg, env, model, n_clusters: int = 9):
        super().__init__(cfg, env, model)
        n_clusters = max(1, min(n_clusters, env.n_clients // 2))
        order = np.argsort(-env.fanout)
        self.clusters = [order[i::n_clusters] for i in range(n_clusters)]
        self.heads = [int(c[np.argmax(env.fanout[c])]) for c in self.clusters]

    def communicate(self, part, ledger, t_now):
        env, d = self.env, self.payload_bits()
        lp = env.link_params
        for c, h in zip(self.clusters, self.heads):
            for i in c:
                if int(i) == h:
                    continue
                dist = env.lisl_distance(int(i), h, t_now)
                dist = dist if np.isfinite(dist) else 3e6
                ledger.add_intra(2, 2 * e_lisl(d, lp.lisl_rate, dist, lp),
                                 2 * t_lisl(d, lp.lisl_rate, dist, lp))
        elect = self.heads[0]
        for h in self.heads[1:]:
            dist = env.lisl_distance(h, elect, t_now)
            dist = dist if np.isfinite(dist) else 3e6
            ledger.add_intra(2, 2 * e_lisl(d, lp.lisl_rate, dist, lp),
                             2 * t_lisl(d, lp.lisl_rate, dist, lp))
        wait, gdist = env.gs_window_wait(elect, t_now)
        ledger.add_gs(2, 2 * e_gs(d, lp.gs_rate, gdist, lp),
                      2 * t_gs(d, lp.gs_rate, gdist, lp))
        return wait


class FedSCS(_Engine):
    name = "FedSCS"

    def select(self, r):
        util = -self.et / self.et.max() - 0.5 * self.tt / self.tt.max()
        noise = self.rng.normal(0, 0.1, len(util))
        return np.argsort(-(util + noise))[: self.cfg.select_m]

    def communicate(self, part, ledger, t_now):
        env, d = self.env, self.payload_bits()
        lp = env.link_params
        waits = []
        for i in part:
            dist = 1.2e6
            ledger.add_intra(4, 4 * e_lisl(d, lp.lisl_rate, dist, lp),
                             4 * t_lisl(d, lp.lisl_rate, dist, lp))
            wait, gdist = env.gs_window_wait(int(i), t_now)
            waits.append(wait)
            ledger.add_gs(2, 2 * e_gs(d, lp.gs_rate, gdist, lp),
                          2 * t_gs(d, lp.gs_rate, gdist, lp))
        wmax = max(waits)
        ledger.add_wait(float(np.sum(wmax - np.asarray(waits))))
        return wmax


class FedOrbit(FedSCS):
    name = "FedOrbit"

    def payload_bits(self):
        return self.cfg.model_bits * self.cfg.minifloat_bits / 32.0

    def _arith_scale(self):
        return self.cfg.arith_scale


REFERENCE_BASELINES = {b.name: b for b in (FedSyn, FedLEO, FELLO, FedSCS,
                                           FedOrbit)}
