"""Regenerate tests/golden_engine.json — pre-refactor reference ledgers.

    PYTHONPATH=src python tests/golden_capture.py

The JSON pins the EnergyLedger of one CroSatFL session and one run per
baseline at fixed seed on the shared tiny setup (the same fixture
tests/test_session.py uses), produced by the FROZEN pre-refactor
implementations in tests/reference_impl.py. The ledger is pure host-side
numpy, so it is reproducible across processes and machines; model weights
are NOT pinned here (XLA CPU results are only bit-reproducible within one
process — test_engine_parity.py compares weights against reference_impl
in-process instead).

Regenerate ONLY when an intentional accounting/protocol change
invalidates the reference, and say so in the commit message.
"""
import dataclasses
import hashlib
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402

from repro.constellation import ConstellationEnv  # noqa: E402
from repro.core.starmask import StarMaskParams  # noqa: E402
from repro.data.synth import dirichlet_partition, make_dataset  # noqa: E402
from repro.fl.client import ImageFLModel  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "golden_engine.json")


def build_setup():
    ds = make_dataset("eurosat-sim", n=600, seed=0)
    test = make_dataset("eurosat-sim", n=200, seed=99)
    n_clients = 8
    parts = dirichlet_partition(ds.y, n_clients, alpha=100.0, seed=0)
    env = ConstellationEnv(
        n_clients=n_clients,
        n_samples=np.array([len(p) for p in parts], float), seed=0)
    model = ImageFLModel(ds, parts, test)
    return env, model


def session_config(model):
    from repro.core.session import SessionConfig
    return SessionConfig(edge_rounds=3, local_epochs=1, k_nbr=2,
                         model_bits=model.model_bits(),
                         starmask=StarMaskParams(k_max=4, m_min=2))


def baseline_config(model):
    from repro.fl.baselines import BaselineConfig
    return BaselineConfig(rounds=2, local_epochs=1,
                          model_bits=model.model_bits())


def weights_digest(w) -> str:
    flat, _ = jax.tree_util.tree_flatten_with_path(w)
    h = hashlib.sha256()
    for path, leaf in flat:
        h.update(str(path).encode())
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def main():
    from reference_impl import REFERENCE_BASELINES, reference_session_run

    golden = {}
    env, model = build_setup()
    _, ledger, _ = reference_session_run(session_config(model), env, model)
    golden["CroSatFL"] = {"ledger": dataclasses.asdict(ledger)}

    for name, ref_cls in REFERENCE_BASELINES.items():
        env, model = build_setup()
        _, ledger, _ = ref_cls(baseline_config(model), env, model).run()
        golden[name] = {"ledger": dataclasses.asdict(ledger)}

    with open(OUT, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {OUT}")
    for k, v in golden.items():
        print(f"{k:10s} wait={v['ledger']['waiting_time_s']:.6g} "
              f"gs={v['ledger']['gs_count']}")


if __name__ == "__main__":
    main()
