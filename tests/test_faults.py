"""Fault injection + recovery (repro.faults, DESIGN.md §13).

Layers under test:

* the fault model: seeded ``FaultSchedule`` generators are pure values,
  the ``FaultState`` live view scopes outages/crashes/payload faults
  correctly and JSON-round-trips;
* the recovery policies: ``Transport``'s retry-with-backoff gate charges
  real energy per failed attempt (mirror-exact), ``force_skip`` carries
  Skip-One fairness, master failover lands in the trace;
* the kernel extension: fault kinds slot into ``EventQueue``'s total
  order (recoveries resolve before faults at equal time) and pending
  future events survive a checkpoint;
* the golden-path guarantee: a session with NO schedule (or an EMPTY
  one) stays bit-identical to tests/golden_engine.json;
* checkpoint hardening: torn/corrupted checkpoints are detected
  (``CheckpointCorrupt``) and resume falls back to the last good round
  boundary.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # pragma: no cover - env dep
    from mini_hypothesis import given, settings, strategies as st

from repro.core.energy import EnergyLedger, LinkParams
from repro.core.skipone import SkipOneState, force_skip
from repro.faults import (FaultInjector, FaultSchedule, FaultState,
                          LinkOutage, MasterFailure, PayloadCorruption,
                          PayloadLoss, SatCrash, smoke_schedule)
from repro.fl.engine.transport import Transport
from repro.sim.events import (CONTACT_OPEN, LINK_DOWN, LINK_UP,
                              PAYLOAD_CORRUPT, PAYLOAD_LOSS, SAT_CRASH,
                              EventQueue)

SETTINGS = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# Fault model
# ---------------------------------------------------------------------------

class TestFaultSchedule:
    def test_sorted_and_immutable(self):
        sch = FaultSchedule((MasterFailure(50.0, 1),
                             LinkOutage(10.0, 5.0),
                             SatCrash(10.0, 3, 20.0)))
        assert [f.t for f in sch.faults] == [10.0, 10.0, 50.0]
        with pytest.raises(dataclasses.FrozenInstanceError):
            sch.seed = 9

    def test_poisson_seed_determines_campaign(self):
        kw = dict(n_clusters=4, n_clients=16, outage_rate_per_h=3.0,
                  crash_rate_per_h=1.0, master_fail_rate_per_h=1.0,
                  payload_rate_per_h=2.0, drift_rate_per_h=1.0)
        a = FaultSchedule.poisson(7200.0, seed=3, **kw)
        b = FaultSchedule.poisson(7200.0, seed=3, **kw)
        c = FaultSchedule.poisson(7200.0, seed=4, **kw)
        assert a.faults == b.faults and len(a) > 0
        assert a.faults != c.faults
        assert all(0.0 <= f.t < 7200.0 for f in a.faults)

    def test_gilbert_elliott_bursts(self):
        ge = FaultSchedule.gilbert_elliott(3600.0, seed=2, link="gs",
                                           cluster=1, p_g2b=0.5, p_b2g=0.5)
        assert len(ge) > 0
        assert all(isinstance(f, LinkOutage) and f.link == "gs"
                   and f.cluster == 1 and f.duration_s > 0
                   for f in ge.faults)

    def test_smoke_schedule_has_the_demo_faults(self):
        sch = smoke_schedule(seed=0)
        kinds = [type(f) for f in sch.faults if f.t == 0.0]
        for k in (MasterFailure, LinkOutage, SatCrash, PayloadCorruption):
            assert k in kinds

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 500), horizon=st.integers(600, 14400))
    def test_property_schedule_replay_deterministic(self, seed, horizon):
        kw = dict(n_clusters=3, n_clients=9, outage_rate_per_h=4.0,
                  crash_rate_per_h=2.0, master_fail_rate_per_h=1.0,
                  payload_rate_per_h=2.0, drift_rate_per_h=2.0)
        assert (FaultSchedule.poisson(float(horizon), seed=seed, **kw).faults
                == FaultSchedule.poisson(float(horizon), seed=seed,
                                         **kw).faults)


class TestFaultState:
    def test_outage_scoping(self):
        fs = FaultState()
        fs.outage_until[("lisl", 2)] = 100.0
        fs.outage_until[("lisl", None)] = 50.0
        assert fs.outage_end("lisl", 2, 0.0) == 100.0   # cluster-scoped wins
        assert fs.outage_end("lisl", 1, 0.0) == 50.0    # global applies
        assert fs.outage_end("lisl", 1, 60.0) == 0.0    # expired
        assert fs.outage_end("gs", 2, 0.0) == 0.0       # other link class

    def test_crash_view(self):
        fs = FaultState()
        fs.crashed[3] = 500.0
        assert fs.down(3, 100.0) and not fs.down(3, 500.0)
        assert fs.down_sats(100.0) == [3] and fs.down_sats(501.0) == []

    def test_payload_fault_one_shot_and_scoped(self):
        fs = FaultState()
        fs.payload_pending[(PAYLOAD_CORRUPT, 1)] = 1
        fs.payload_pending[(PAYLOAD_LOSS, None)] = 1
        assert fs.take_payload_fault(1) == PAYLOAD_CORRUPT
        assert fs.take_payload_fault(1) == PAYLOAD_LOSS   # falls to global
        assert fs.take_payload_fault(1) is None           # all consumed

    def test_json_roundtrip(self):
        fs = FaultState(max_retries=6, backoff0_s=15.0)
        fs.outage_until[("gs", None)] = 80.0
        fs.crashed[2] = 900.0
        fs.payload_pending[(PAYLOAD_LOSS, 0)] = 2
        fs.dropped = 1
        fs2 = FaultState.from_dict(json.loads(json.dumps(fs.to_dict())))
        assert fs2.to_dict() == fs.to_dict()
        assert fs2.max_retries == 6 and fs2.backoff0_s == 15.0
        assert fs2.outage_end("gs", 3, 0.0) == 80.0 and fs2.down(2, 0.0)


# ---------------------------------------------------------------------------
# Transport retry gate
# ---------------------------------------------------------------------------

def _tp(faults=None, obs=None):
    led = EnergyLedger()
    return led, Transport(led, LinkParams(), 1e6, obs=obs, faults=faults)


class TestTransportFaultGate:
    def test_empty_state_is_bitfree(self):
        led_f, tp_f = _tp(faults=FaultState())
        led_c, tp_c = _tp()
        for tp in (tp_f, tp_c):
            tp.gs(2, 5e5)
            tp.intra(3, 1e6)
            tp.inter(1, 2e6)
        assert dataclasses.asdict(led_f) == dataclasses.asdict(led_c)

    def test_outage_retries_charge_real_energy_then_deliver(self):
        """200s LISL outage, 30s base backoff: attempts at +30,+90,+210
        burn 3 full copies + 210s of retry wait, then the real copy
        lands — 4x the clean energy, bit-exactly."""
        fs = FaultState(max_retries=4, backoff0_s=30.0)
        fs.outage_until[("lisl", None)] = 200.0
        led_f, tp_f = _tp(faults=fs)
        tp_f.intra(1, 1e6)
        led_c, tp_c = _tp()
        for _ in range(4):                     # same float-add sequence
            tp_c.intra(1, 1e6)
        assert led_f.intra_lisl_count == 4
        assert led_f.lisl_energy_j == led_c.lisl_energy_j
        assert led_f.waiting_time_s == 30.0 + 60.0 + 120.0
        assert fs.dropped == 0

    def test_capped_retries_drop_degraded(self):
        """An outage longer than the whole backoff budget: max_retries
        charged attempts, then the batch is DROPPED (no final copy)."""
        fs = FaultState(max_retries=4, backoff0_s=30.0)
        fs.outage_until[("gs", None)] = 1e9
        led, tp = _tp(faults=fs)
        tp.gs(1, 5e5)
        assert led.gs_count == 4               # 4 failed copies, no 5th
        assert led.waiting_time_s == 30.0 + 60.0 + 120.0 + 240.0
        assert fs.dropped == 1

    def test_payload_corruption_costs_one_retransmission(self):
        fs = FaultState()
        fs.payload_pending[(PAYLOAD_CORRUPT, None)] = 1
        led_f, tp_f = _tp(faults=fs)
        tp_f.intra(2, 1e6)                     # corrupted copy + resend
        tp_f.intra(2, 1e6)                     # fault consumed: normal
        led_c, tp_c = _tp()
        for _ in range(3):
            tp_c.intra(2, 1e6)
        assert dataclasses.asdict(led_f) == dataclasses.asdict(led_c)

    def test_mirror_reconciles_under_faults(self):
        """Every retry joule/second hits the observer exactly once: the
        mirror ledger stays bit-exact through outage retries, payload
        retransmissions, and a degraded drop."""
        from repro.obs import TracingObserver
        obs = TracingObserver()
        fs = FaultState(max_retries=3, backoff0_s=10.0)
        fs.outage_until[("lisl", None)] = 25.0
        fs.payload_pending[(PAYLOAD_LOSS, None)] = 1
        led, tp = _tp(faults=fs, obs=obs)
        tp.intra(2, 1e6)                       # retries through the outage
        led.wall_clock_s = 1000.0
        fs.outage_until[("gs", None)] = 1e9
        tp.gs(1, 5e5)                          # capped -> drop
        obs.mirror.wall_clock_s = led.wall_clock_s
        rec = obs.reconcile(led)
        assert rec["exact"], rec["fields"]
        actions = {e["action"] for e in obs.tracer.events
                   if e["kind"] == "recovery"}
        assert {"retransmit", "retry", "drop"} <= actions


class TestLedgerValidation:
    @pytest.mark.parametrize("call", [
        lambda led: led.add_intra(1, float("nan"), 0.1),
        lambda led: led.add_inter(1, 0.1, -0.5),
        lambda led: led.add_gs(-1, 0.1, 0.1),
        lambda led: led.add_train(float("nan"), 1.0),
        lambda led: led.add_wait(-1.0),
        lambda led: led.add_wait(float("nan")),
    ])
    def test_nan_negative_rejected_at_entry(self, call):
        led = EnergyLedger()
        before = dataclasses.asdict(led)
        with pytest.raises(ValueError, match="NaN/negative"):
            call(led)
        assert dataclasses.asdict(led) == before   # rejected atomically

    def test_zero_is_legal(self):
        led = EnergyLedger()
        led.add_intra(0, 0.0, 0.0)
        led.add_wait(0.0)
        led.add_train(0.0, 0.0)


# ---------------------------------------------------------------------------
# Kernel extension + injector checkpointing
# ---------------------------------------------------------------------------

class TestEventQueueFaultKinds:
    def test_recoveries_resolve_before_faults_at_equal_time(self):
        q = EventQueue(seed=0)
        q.push(10.0, CONTACT_OPEN, sat=1)
        q.push(10.0, LINK_DOWN, link="lisl")
        q.push(10.0, LINK_UP)
        q.push(10.0, SAT_CRASH, sat=2)
        kinds = [ev.kind for ev in q.pop_until(10.0)]
        assert kinds == [LINK_UP, LINK_DOWN, SAT_CRASH, CONTACT_OPEN]

    def test_pending_events_survive_checkpoint(self):
        q = EventQueue(seed=5)
        q.push(100.0, LINK_DOWN, cluster=1, link="lisl", duration_s=50.0)
        q.push(150.0, LINK_UP, cluster=1, link="lisl")
        q.push(100.0, SAT_CRASH, sat=3, duration_s=600.0)
        sd = json.loads(json.dumps(q.state_dict()))
        assert sd["pending"] == 3
        q2 = EventQueue(seed=5)
        q2.load_state_dict(sd)
        a = [(e.t, e.kind, e.cluster, e.sat, e.payload)
             for e in q.pop_until(1e9)]
        b = [(e.t, e.kind, e.cluster, e.sat, e.payload)
             for e in q2.pop_until(1e9)]
        assert a == b

    def test_load_rejects_unknown_kind(self):
        q = EventQueue(seed=1)
        q.push(5.0, LINK_DOWN)
        sd = q.state_dict()
        sd["events"][0][4]["kind"] = "alien_invasion"
        q2 = EventQueue()
        with pytest.raises(ValueError, match="unknown event kind "
                                             "'alien_invasion'"):
            q2.load_state_dict(sd)

    @pytest.mark.parametrize("bad", [
        "not-a-dict", {"seq": 0}, {"rng": {}},
        {"seq": 0, "rng": {}, "events": [[1.0, 0, 0.0, 0]]},
    ])
    def test_load_rejects_malformed_state(self, bad):
        q = EventQueue()
        with pytest.raises(ValueError):
            q.load_state_dict(bad)


class _Bindable:
    round_idx = 0


class TestInjectorCheckpoint:
    def _bound(self, sch):
        inj = FaultInjector(sch)
        inj.bind(None, None, _Bindable())
        return inj

    def test_snapshot_resumes_pending_future_events(self):
        sch = FaultSchedule((LinkOutage(100.0, 50.0),
                             SatCrash(400.0, 2, 300.0),
                             MasterFailure(900.0, 0)))
        inj = self._bound(sch)
        inj.kernel.pop_until(200.0)            # mid-campaign
        inj.state.outage_until[("lisl", None)] = 150.0
        sd = json.loads(json.dumps(inj.state_dict()))
        inj2 = FaultInjector(sch)
        inj2.load_state_dict(sd)
        assert inj2.state.to_dict() == inj.state.to_dict()
        rest = [(e.t, e.kind) for e in inj.kernel.pop_until(1e9)]
        rest2 = [(e.t, e.kind) for e in inj2.kernel.pop_until(1e9)]
        # crash @400 + its reboot @700 + master fail @900 still pending
        assert rest == rest2 and len(rest) == 3

    def test_load_none_clears_reused_injector(self):
        inj = self._bound(FaultSchedule((LinkOutage(10.0, 5.0),)))
        inj.state.crashed[1] = 99.0
        inj.load_state_dict(None)
        assert len(inj.kernel) == 0 and not inj.state.crashed

    def test_state_identity_stable_across_load(self):
        """Transport views hold a reference to the injector's FaultState;
        reset/load must mutate IN PLACE, never swap the object."""
        inj = self._bound(smoke_schedule(seed=1))
        view = inj.state
        inj.load_state_dict(json.loads(json.dumps(inj.state_dict())))
        assert inj.state is view
        inj.load_state_dict(None)
        assert inj.state is view

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 300), cut=st.integers(0, 3600))
    def test_property_checkpoint_cut_is_exact(self, seed, cut):
        """Splitting a campaign at ANY time and resuming from the
        snapshot replays the identical remaining fault stream."""
        sch = FaultSchedule.poisson(
            3600.0, seed=seed, n_clusters=3, n_clients=6,
            outage_rate_per_h=6.0, crash_rate_per_h=3.0,
            master_fail_rate_per_h=2.0, payload_rate_per_h=3.0,
            drift_rate_per_h=2.0)
        whole = self._bound(sch)
        full = [(e.t, e.kind, e.cluster, e.sat) for e in
                whole.kernel.pop_until(1e9)]
        split = self._bound(sch)
        head = [(e.t, e.kind, e.cluster, e.sat) for e in
                split.kernel.pop_until(float(cut))]
        resumed = FaultInjector(sch)
        resumed.load_state_dict(json.loads(json.dumps(split.state_dict())))
        tail = [(e.t, e.kind, e.cluster, e.sat) for e in
                resumed.kernel.pop_until(1e9)]
        assert head + tail == full


class TestSkipMany:
    def test_force_skip_bumps_tau_only(self):
        st_ = SkipOneState.init(4)
        st_.phi[:] = 1.0
        before_phi, before_kappa = st_.phi.copy(), st_.kappa.copy()
        force_skip(st_, 2)
        force_skip(st_, 2)
        assert st_.tau[2] == 2 and st_.tau[[0, 1, 3]].sum() == 0
        np.testing.assert_array_equal(st_.phi, before_phi)
        np.testing.assert_array_equal(st_.kappa, before_kappa)


# ---------------------------------------------------------------------------
# Checkpoint hardening
# ---------------------------------------------------------------------------

class TestCkptHardening:
    def test_crc_in_manifest_and_clean_roundtrip(self, tmp_path):
        from repro.ckpt import load_pytree, save_pytree
        tree = {"a": np.arange(12.0).reshape(3, 4), "b": np.ones(5)}
        p = str(tmp_path / "t.npz")
        save_pytree(tree, p)
        with np.load(p) as z:
            manifest = json.loads(str(z["manifest"]))
        assert isinstance(manifest["crc32"], int)
        out = load_pytree(p, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      tree["a"])

    def test_bitrot_detected(self, tmp_path):
        """Same keys/shapes, different content, stale checksum — the
        silent-corruption case crc32 exists for."""
        from repro.ckpt import CheckpointCorrupt, load_pytree, save_pytree
        tree = {"w": np.arange(6.0)}
        p = str(tmp_path / "t.npz")
        save_pytree(tree, p)
        with np.load(p) as z:
            manifest = str(z["manifest"])
        np.savez(p, manifest=manifest, leaf_0=np.arange(6.0) + 1e-9)
        with pytest.raises(CheckpointCorrupt, match="checksum mismatch"):
            load_pytree(p, tree)

    def test_torn_file_detected(self, tmp_path):
        from repro.ckpt import CheckpointCorrupt, load_pytree, save_pytree
        tree = {"w": np.zeros((64, 64))}
        p = str(tmp_path / "t.npz")
        save_pytree(tree, p)
        data = open(p, "rb").read()
        with open(p, "wb") as f:
            f.write(data[:len(data) // 2])     # torn write
        with pytest.raises(CheckpointCorrupt):
            load_pytree(p, tree)

    def _mini_state(self, round_idx):
        import jax.numpy as jnp
        from repro.core.session import SessionState
        return SessionState(round_idx, {"w": jnp.arange(6.0) + round_idx},
                            [SkipOneState.init(3)], np.array([0, 1]),
                            jax.random.PRNGKey(7), EnergyLedger())

    def test_fallback_to_last_good_round_boundary(self, tmp_path):
        from repro.ckpt import load_latest_session, save_session
        s1, s2 = self._mini_state(1), self._mini_state(2)
        save_session(s1, str(tmp_path / "step_1"))
        save_session(s2, str(tmp_path / "step_2"))
        like = s1.cluster_models
        st, path = load_latest_session(str(tmp_path), like)
        assert st.round_idx == 2 and path.endswith("step_2")
        # corrupt the newest shard: resume must fall back to step_1
        with open(tmp_path / "step_2" / "models.npz", "wb") as f:
            f.write(b"garbage")
        st, path = load_latest_session(str(tmp_path), like)
        assert st.round_idx == 1 and path.endswith("step_1")
        np.testing.assert_array_equal(np.asarray(st.cluster_models["w"]),
                                      np.arange(6.0) + 1)
        # nothing loadable at all
        with open(tmp_path / "step_1" / "models.npz", "wb") as f:
            f.write(b"garbage")
        st, path = load_latest_session(str(tmp_path), like)
        assert st is None and path is None

    def test_meta_schema_unchanged_without_faults(self, tmp_path):
        from repro.ckpt import save_session
        save_session(self._mini_state(1), str(tmp_path / "step_1"))
        with open(tmp_path / "step_1" / "meta.json") as f:
            meta = json.load(f)
        assert "faults" not in meta


# ---------------------------------------------------------------------------
# Engine-level: golden parity + recovery demo
# ---------------------------------------------------------------------------

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_engine.json")


class TestEngineUnderFaults:
    def test_empty_schedule_bit_identical_to_golden(self):
        """THE golden-path acceptance: attaching an EMPTY FaultSchedule
        leaves the CroSatFL ledger bit-identical to the pinned golden
        (i.e. identical to an unattached run)."""
        from golden_capture import build_setup, session_config
        from repro.core.session import Session
        env, model = build_setup()
        cfg = session_config(model)
        _, led, _ = Session(cfg, env, model,
                            faults=FaultSchedule()).run()
        with open(GOLDEN) as f:
            want = json.load(f)["CroSatFL"]["ledger"]
        got = dataclasses.asdict(led)
        assert set(got) == set(want)
        for k, v in want.items():
            assert got[k] == v, (k, got[k], v)

    def test_masterfailure_outage_round_recovers(self):
        """The ISSUE's recovery demo: a round hit by MasterFailure +
        LISL outage + crash + payload corruption completes, the failover
        is in the trace, retries are charged to the ledger, and the
        trace mirror still reconciles bit-exactly."""
        from repro.faults.chaos import build_engine, tiny_setup
        from repro.obs import TracingObserver
        env, model = tiny_setup(seed=0)
        sch = FaultSchedule((MasterFailure(0.0, 0),
                             LinkOutage(0.0, 200.0),
                             SatCrash(0.0, 1, 1e9),
                             PayloadCorruption(0.0)))
        obs = TracingObserver()
        eng = build_engine("CroSatFL", env, model, rounds=2, seed=0,
                           observer=obs, faults=sch)
        _, led, _ = eng.run()                  # completing == no deadlock
        assert obs.reconcile(led)["exact"]
        recov = [e for e in obs.tracer.events if e["kind"] == "recovery"]
        assert any(e["action"] == "failover" and e["cluster"] == 0
                   for e in recov)
        assert obs.metrics.total("recoveries", action="retry") >= 1
        assert obs.metrics.total("wait_s", cause="retry") > 0
        assert obs.metrics.total("recoveries", action="skip_crashed") >= 1
        assert obs.metrics.total("faults") >= 4
        # retries are charged to the REAL ledger: the backoff component
        # sits inside waiting_time_s (and mirror exactness above proves
        # every retry joule/second landed exactly once — a clean-twin
        # comparison would be ill-posed, since failover legitimately
        # moves masters and with them the GS window waits)
        retry_wait = obs.metrics.total("wait_s", cause="retry")
        assert 0 < retry_wait <= led.waiting_time_s

    def test_fault_timeline_in_chrome_trace(self, tmp_path):
        from repro.faults.chaos import build_engine, tiny_setup
        from repro.obs import TracingObserver
        env, model = tiny_setup(seed=0)
        obs = TracingObserver()
        build_engine("CroSatFL", env, model, rounds=1, seed=0,
                     observer=obs,
                     faults=FaultSchedule((MasterFailure(0.0, 0),))).run()
        track_meta = [e for e in obs.tracer.chrome_events()
                      if e.get("name") == "thread_name"
                      and e["args"]["name"] == "faults"]
        assert track_meta, "fault timeline track missing from export"
