"""Executor layer: sequential / batched / sharded round execution
(DESIGN.md §9, §12).

Parity contract: the sequential executor is the golden bit-parity
reference (pinned in test_engine_parity.py and against
tests/golden_engine.json); the batched and sharded executors must match
it within float tolerance on weights while their LEDGER — pure host-side
accounting, untouched by how training executes — stays bit-for-bit equal
across every (executor, pacing) cell.

Multi-device sharding is validated in a subprocess (sharded_check.py)
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — this
process deliberately runs single-device (conftest.py), where the sharded
executor degrades to a 1-pod mesh.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.fl.engine import (AsyncPacing, EngineConfig, RoundEngine,
                             SemiSyncPacing, SingleCluster, GSStarMixing,
                             TopMEnergyUtility, make_crosatfl)
from repro.fl.exec import (EXECUTOR_NAMES, BatchedExecutor,
                           SequentialExecutor, ShardedExecutor)

from golden_capture import build_setup, session_config

HERE = os.path.dirname(__file__)
GOLDEN = os.path.join(HERE, "golden_engine.json")
TOL = dict(atol=2e-4, rtol=2e-4)

PACINGS = {"sync": lambda: None,
           "semi-sync": lambda: SemiSyncPacing(quantile=0.5),
           "async": lambda: AsyncPacing()}


@pytest.fixture(scope="module")
def setup():
    return build_setup()


def engine(env, model, *, executor=None, rounds=None, mixing_backend=None,
           pacing=None, batched_exec=False):
    scfg = session_config(model)
    cfg = scfg.engine_config()
    if rounds is not None:
        cfg = dataclasses.replace(cfg, rounds=rounds)
    cfg = dataclasses.replace(cfg, executor=executor,
                              batched_exec=batched_exec)
    return make_crosatfl(cfg, env, model, k_nbr=scfg.k_nbr,
                         starmask=scfg.starmask,
                         mixing_backend=mixing_backend, pacing=pacing)


def assert_weights_close(w_a, w_b, **tol):
    for a, b in zip(jax.tree.leaves(w_a), jax.tree.leaves(w_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


class _NoFleetModel:
    """ImageFLModel minus every fleet entry point (the pre-fleet-surface
    model shape)."""

    _HIDE = ("init_fleet", "client_step", "fleet_round")

    def __init__(self, m):
        self._m = m

    def __getattr__(self, name):
        if name in self._HIDE:
            raise AttributeError(name)
        return getattr(self._m, name)


class TestFleetRound:
    def test_fleet_matches_sequential_cluster_rounds(self, setup):
        """Unit parity: fleet_round == per-cluster cluster_round, including
        a padded (short) cluster and a zero-participant cluster."""
        env, model = setup
        K = 3
        w0 = model.init(jax.random.PRNGKey(0))
        stacked = model.stack([w0] * K)
        parts = [np.array([0, 1, 2]), np.array([3]), np.array([], int)]
        subs = list(jax.random.split(jax.random.PRNGKey(1), K))

        seq = [model.cluster_round(
                   jax.tree.map(lambda l, kc=kc: l[kc], stacked), parts[kc],
                   env.n_samples[parts[kc]], 1, subs[kc])
               for kc in range(K)]
        fleet = model.fleet_round(stacked, parts, env.n_samples, 1, subs,
                                  pad_to=4)
        for kc in range(K):
            assert_weights_close(
                jax.tree.map(lambda l, kc=kc: l[kc], fleet), seq[kc], **TOL)
        # the empty cluster kept its model bit-for-bit
        for a, b in zip(jax.tree.leaves(fleet), jax.tree.leaves(w0)):
            np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b))

    def test_device_data_built_once(self, setup):
        env, model = setup
        X1 = model._device_data()
        X2 = model._device_data()
        assert X1[0] is X2[0]          # one-time device-resident tensor

    def test_client_step_memoized(self, setup):
        """The executors' jit caches key on the step fn's identity."""
        env, model = setup
        assert model.client_step(1) is model.client_step(1)
        assert model.client_step(1) is not model.client_step(2)

    def test_padded_memoized(self, setup):
        env, model = setup
        a = model._padded(0)
        b = model._padded(0)
        assert a[0] is b[0]            # repeat rounds reuse device buffers

    def test_model_bits_cached(self, setup):
        env, model = setup
        assert model.model_bits() == model.model_bits()
        assert model._model_bits is not None


class TestExecutorPacingMatrix:
    """Every executor x every pacing family: ledgers bit-equal across
    executors within a pacing, weights within tolerance; the Sync row
    additionally bit-equals the golden reference ledger."""

    @pytest.mark.parametrize("pacing_name", list(PACINGS),
                             ids=list(PACINGS))
    def test_matrix_cell(self, setup, pacing_name):
        env, model = setup
        make_pacing = PACINGS[pacing_name]
        results = {}
        for ex in EXECUTOR_NAMES:
            w, led, _ = engine(env, model, executor=ex,
                               pacing=make_pacing()).run()
            results[ex] = (dataclasses.asdict(led), w)
        led_seq, w_seq = results["sequential"]
        for ex in ("batched", "sharded"):
            led, w = results[ex]
            assert led == led_seq, f"{ex} ledger drifted under {pacing_name}"
            assert_weights_close(w, w_seq, **TOL)
        if pacing_name == "sync":
            with open(GOLDEN) as f:
                golden = json.load(f)
            assert led_seq == golden["CroSatFL"]["ledger"]

    def test_history_matches_sequential(self, setup):
        env, model = setup
        ev = lambda p, r: model.evaluate(p)   # noqa: E731
        _, _, hist_s = engine(env, model, executor="sequential").run(
            eval_fn=ev)
        _, _, hist_b = engine(env, model, executor="batched").run(eval_fn=ev)
        for a, b in zip(hist_b, hist_s):
            assert a["round"] == b["round"]
            assert abs(a["acc"] - b["acc"]) <= 0.03

    def test_pallas_mixing_matches_einsum(self, setup):
        env, model = setup
        w_e, led_e, _ = engine(env, model, executor="batched").run()
        w_p, led_p, _ = engine(env, model, executor="batched",
                               mixing_backend="pallas").run()
        assert dataclasses.asdict(led_p) == dataclasses.asdict(led_e)
        assert_weights_close(w_p, w_e, atol=1e-5, rtol=1e-5)

    def test_zero_participant_round_completes(self, setup):
        env, model = setup
        eng = RoundEngine(
            EngineConfig(rounds=1, local_epochs=1,
                         model_bits=model.model_bits(), executor="batched"),
            env, model,
            clustering=SingleCluster(),
            selection=TopMEnergyUtility(select_m=0),
            mixing=GSStarMixing(), name="empty-batched")
        w, led, _ = eng.run()
        assert led.train_energy_j == 0.0
        assert np.isfinite(led.wall_clock_s)


class TestExecutorResolution:
    def test_deprecated_bool_warns_and_matches_batched(self, setup):
        """batched_exec=True still works, warns, and runs the batched
        executor — ledger and weights identical to executor='batched'."""
        env, model = setup
        with pytest.warns(DeprecationWarning, match="batched_exec"):
            eng = engine(env, model, batched_exec=True)
        assert eng.executor.name == "batched"
        w_d, led_d, _ = eng.run()
        w_b, led_b, _ = engine(env, model, executor="batched").run()
        assert dataclasses.asdict(led_d) == dataclasses.asdict(led_b)
        for a, b in zip(jax.tree.leaves(w_d), jax.tree.leaves(w_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_deprecated_bool_silent_fallback_without_fleet(self, setup):
        """The shim preserves the old bool's semantics exactly: a model
        with no fleet path silently runs sequentially."""
        env, model = setup
        with pytest.warns(DeprecationWarning):
            eng = engine(env, _NoFleetModel(model), batched_exec=True)
        assert eng.executor.name == "sequential"

    def test_explicit_batched_requires_fleet_surface(self, setup):
        env, model = setup
        eng = engine(env, _NoFleetModel(model), executor="batched")
        with pytest.raises(TypeError, match="fleet"):
            eng.run()

    def test_unknown_executor_name(self, setup):
        env, model = setup
        with pytest.raises(KeyError, match="unknown executor"):
            engine(env, model, executor="warp-drive")

    def test_executor_instance_passes_through(self, setup):
        env, model = setup
        inst = BatchedExecutor()
        eng = engine(env, model, executor=inst)
        assert eng.executor is inst

    def test_default_is_sequential(self, setup):
        env, model = setup
        assert isinstance(engine(env, model).executor, SequentialExecutor)

    def test_sharded_single_device_degrades_to_one_pod(self, setup):
        env, model = setup
        eng = engine(env, model, executor="sharded")
        eng.run(rounds=1)
        assert isinstance(eng.executor, ShardedExecutor)
        assert eng.executor.mesh.shape["pod"] == 1


class TestShardedMultiDevice:
    def test_sharded_check_subprocess(self):
        """Real pod sharding needs >1 device; conftest.py keeps this
        process single-device on purpose, so the 8-device validation runs
        in a subprocess (same script CI's perf-smoke environment uses)."""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["JAX_PLATFORMS"] = "cpu"
        root = os.path.join(HERE, "..")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src"), HERE,
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
        proc = subprocess.run(
            [sys.executable, os.path.join(HERE, "sharded_check.py")],
            capture_output=True, text=True, env=env, cwd=root, timeout=600)
        assert proc.returncode == 0, \
            f"sharded_check failed:\n{proc.stdout}\n{proc.stderr}"
        assert "PASS" in proc.stdout


class TestEvalEvery:
    def test_history_keeps_true_round_index(self, setup):
        env, model = setup
        ev = lambda p, r: model.evaluate(p)   # noqa: E731
        eng = engine(env, model, rounds=5)
        _, _, hist = eng.run(eval_fn=ev, eval_every=2)
        # rounds 1 and 3 hit the cadence; the final round always evals
        assert [h["round"] for h in hist] == [1, 3, 4]

    def test_default_evals_every_round(self, setup):
        env, model = setup
        ev = lambda p, r: model.evaluate(p)   # noqa: E731
        _, _, hist = engine(env, model, rounds=3).run(eval_fn=ev)
        assert [h["round"] for h in hist] == [0, 1, 2]


class TestPlanCache:
    def test_repeat_runs_reuse_plan(self, setup):
        env, model = setup
        eng = engine(env, model, executor="batched", rounds=1)
        calls = []
        orig = eng.clustering.build
        eng.clustering.build = lambda ctx, key: (calls.append(1),
                                                 orig(ctx, key))[1]
        eng.run()
        eng.run()
        assert len(calls) == 1         # second run hit the cache

    def test_cached_plan_not_mutated_by_migration(self, setup):
        """state.masters must be a copy: master migration writes through it
        and the cached plan serves later runs."""
        env, model = setup
        eng = engine(env, model, rounds=2)
        eng.run()
        masters_after_first = eng._plan_cache[1].masters.copy()
        eng.run()
        np.testing.assert_array_equal(eng._plan_cache[1].masters,
                                      masters_after_first)
