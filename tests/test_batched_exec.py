"""Device-resident batched round execution (DESIGN.md §9).

Parity contract: the sequential path is the golden bit-parity reference
(pinned in test_engine_parity.py); the batched path must match it within
float tolerance on weights while its LEDGER — which is pure host-side
accounting, untouched by how training executes — stays bit-for-bit, still
equal to tests/golden_engine.json.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.fl.engine import (AsyncPacing, EngineConfig, RoundEngine,
                             SemiSyncPacing, SingleCluster, GSStarMixing,
                             TopMEnergyUtility, make_crosatfl)

from golden_capture import build_setup, session_config

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_engine.json")
TOL = dict(atol=2e-4, rtol=2e-4)


@pytest.fixture(scope="module")
def setup():
    return build_setup()


def engine(env, model, *, batched, rounds=None, mixing_backend=None,
           pacing=None):
    scfg = session_config(model)
    cfg = scfg.engine_config()
    if rounds is not None:
        cfg = dataclasses.replace(cfg, rounds=rounds)
    cfg = dataclasses.replace(cfg, batched_exec=batched)
    return make_crosatfl(cfg, env, model, k_nbr=scfg.k_nbr,
                         starmask=scfg.starmask,
                         mixing_backend=mixing_backend)


def assert_weights_close(w_a, w_b, **tol):
    for a, b in zip(jax.tree.leaves(w_a), jax.tree.leaves(w_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


class TestFleetRound:
    def test_fleet_matches_sequential_cluster_rounds(self, setup):
        """Unit parity: fleet_round == per-cluster cluster_round, including
        a padded (short) cluster and a zero-participant cluster."""
        env, model = setup
        K = 3
        w0 = model.init(jax.random.PRNGKey(0))
        stacked = model.stack([w0] * K)
        parts = [np.array([0, 1, 2]), np.array([3]), np.array([], int)]
        subs = list(jax.random.split(jax.random.PRNGKey(1), K))

        seq = [model.cluster_round(
                   jax.tree.map(lambda l, kc=kc: l[kc], stacked), parts[kc],
                   env.n_samples[parts[kc]], 1, subs[kc])
               for kc in range(K)]
        fleet = model.fleet_round(stacked, parts, env.n_samples, 1, subs,
                                  pad_to=4)
        for kc in range(K):
            assert_weights_close(
                jax.tree.map(lambda l, kc=kc: l[kc], fleet), seq[kc], **TOL)
        # the empty cluster kept its model bit-for-bit
        for a, b in zip(jax.tree.leaves(fleet), jax.tree.leaves(w0)):
            np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b))

    def test_device_data_built_once(self, setup):
        env, model = setup
        X1 = model._device_data()
        X2 = model._device_data()
        assert X1[0] is X2[0]          # one-time device-resident tensor

    def test_padded_memoized(self, setup):
        env, model = setup
        a = model._padded(0)
        b = model._padded(0)
        assert a[0] is b[0]            # repeat rounds reuse device buffers

    def test_model_bits_cached(self, setup):
        env, model = setup
        assert model.model_bits() == model.model_bits()
        assert model._model_bits is not None


class TestBatchedEngineParity:
    def test_matches_sequential_and_golden_ledger(self, setup):
        """The golden-engine scenario: batched ledger bit-equals both the
        sequential run and tests/golden_engine.json; weights and history
        match within tolerance."""
        env, model = setup
        ev = lambda p, r: model.evaluate(p)   # noqa: E731
        w_s, led_s, hist_s = engine(env, model, batched=False).run(eval_fn=ev)
        w_b, led_b, hist_b = engine(env, model, batched=True).run(eval_fn=ev)

        assert dataclasses.asdict(led_b) == dataclasses.asdict(led_s)
        with open(GOLDEN) as f:
            golden = json.load(f)
        assert dataclasses.asdict(led_b) == golden["CroSatFL"]["ledger"]
        assert_weights_close(w_b, w_s, **TOL)
        for a, b in zip(hist_b, hist_s):
            assert a["round"] == b["round"]
            assert abs(a["acc"] - b["acc"]) <= 0.03

    @pytest.mark.parametrize("make_pacing", [
        lambda: SemiSyncPacing(quantile=0.5),
        lambda: AsyncPacing(),
    ], ids=["semi-sync", "async"])
    def test_merge_stacked_matches_merge(self, setup, make_pacing):
        """Pacing policies' stacked merge path == the list merge path."""
        env, model = setup
        scfg = session_config(model)
        kw = dict(k_nbr=scfg.k_nbr, starmask=scfg.starmask)
        cfg = scfg.engine_config()
        w_s, led_s, _ = make_crosatfl(cfg, env, model,
                                      pacing=make_pacing(), **kw).run()
        cfg_b = dataclasses.replace(cfg, batched_exec=True)
        w_b, led_b, _ = make_crosatfl(cfg_b, env, model,
                                      pacing=make_pacing(), **kw).run()
        assert dataclasses.asdict(led_b) == dataclasses.asdict(led_s)
        assert_weights_close(w_b, w_s, **TOL)

    def test_pallas_mixing_matches_einsum(self, setup):
        env, model = setup
        w_e, led_e, _ = engine(env, model, batched=True).run()
        w_p, led_p, _ = engine(env, model, batched=True,
                               mixing_backend="pallas").run()
        assert dataclasses.asdict(led_p) == dataclasses.asdict(led_e)
        assert_weights_close(w_p, w_e, atol=1e-5, rtol=1e-5)

    def test_zero_participant_round_completes(self, setup):
        env, model = setup
        eng = RoundEngine(
            EngineConfig(rounds=1, local_epochs=1,
                         model_bits=model.model_bits(), batched_exec=True),
            env, model,
            clustering=SingleCluster(),
            selection=TopMEnergyUtility(select_m=0),
            mixing=GSStarMixing(), name="empty-batched")
        w, led, _ = eng.run()
        assert led.train_energy_j == 0.0
        assert np.isfinite(led.wall_clock_s)


class TestEvalEvery:
    def test_history_keeps_true_round_index(self, setup):
        env, model = setup
        ev = lambda p, r: model.evaluate(p)   # noqa: E731
        eng = engine(env, model, batched=False, rounds=5)
        _, _, hist = eng.run(eval_fn=ev, eval_every=2)
        # rounds 1 and 3 hit the cadence; the final round always evals
        assert [h["round"] for h in hist] == [1, 3, 4]

    def test_default_evals_every_round(self, setup):
        env, model = setup
        ev = lambda p, r: model.evaluate(p)   # noqa: E731
        _, _, hist = engine(env, model, batched=False, rounds=3).run(
            eval_fn=ev)
        assert [h["round"] for h in hist] == [0, 1, 2]


class TestPlanCache:
    def test_repeat_runs_reuse_plan(self, setup):
        env, model = setup
        eng = engine(env, model, batched=True, rounds=1)
        calls = []
        orig = eng.clustering.build
        eng.clustering.build = lambda ctx, key: (calls.append(1),
                                                 orig(ctx, key))[1]
        eng.run()
        eng.run()
        assert len(calls) == 1         # second run hit the cache

    def test_cached_plan_not_mutated_by_migration(self, setup):
        """state.masters must be a copy: master migration writes through it
        and the cached plan serves later runs."""
        env, model = setup
        eng = engine(env, model, batched=False, rounds=2)
        eng.run()
        masters_after_first = eng._plan_cache[1].masters.copy()
        eng.run()
        np.testing.assert_array_equal(eng._plan_cache[1].masters,
                                      masters_after_first)
