"""Deterministic stand-in for the ``hypothesis`` API surface that
tests/test_property.py uses, for containers where hypothesis is not
installed (this repo forbids ad-hoc pip installs).

Covers exactly: ``given(**strategies)``, ``settings(max_examples=...,
deadline=...)`` stacked above ``given``, and ``strategies.integers(a, b)``
/ ``strategies.floats(a, b)``. Draws are deterministic per test (seeded
by the test's qualified name) and boundary-first: example 0 pins every
parameter to its minimum, example 1 to its maximum, example 2 mixes
min/max alternately, and the rest are uniform draws — so the classic
edge cases (empty reach graphs, k=2, alpha at both ends) are always
exercised regardless of ``max_examples``.

Real hypothesis wins when present: test_property.py imports this module
only as a fallback.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Integers:
    def __init__(self, min_value, max_value):
        self.lo, self.hi = int(min_value), int(max_value)

    def example(self, rng, i, slot):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        if i == 2:
            return self.lo if slot % 2 else self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats:
    def __init__(self, min_value, max_value):
        self.lo, self.hi = float(min_value), float(max_value)

    def example(self, rng, i, slot):
        if i == 0:
            return self.lo
        if i == 1:
            return self.hi
        if i == 2:
            return self.lo if slot % 2 else self.hi
        return float(rng.uniform(self.lo, self.hi))


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value, max_value):
        return _Floats(min_value, max_value)


st = strategies


def settings(**kw):
    """Stores the config on the (already-``given``-wrapped) function; the
    ``given`` wrapper reads it at call time, matching hypothesis's
    ``@settings`` -> ``@given`` stacking order."""
    def deco(fn):
        fn._mh_settings = dict(kw)
        return fn
    return deco


def given(**strategy_kw):
    def deco(fn):
        sig = inspect.signature(fn)
        # pytest must only see the non-drawn params (fixtures)
        fixture_params = [p for name, p in sig.parameters.items()
                         if name not in strategy_kw]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_mh_settings", {})
            n = int(conf.get("max_examples", DEFAULT_MAX_EXAMPLES))
            seed0 = zlib.crc32(fn.__qualname__.encode())
            names = sorted(strategy_kw)
            for i in range(n):
                rng = np.random.default_rng((seed0 + i) % 2**32)
                drawn = {name: strategy_kw[name].example(rng, i, slot)
                         for slot, name in enumerate(names)}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): "
                        f"{fn.__name__}({drawn})") from e

        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        return wrapper
    return deco
