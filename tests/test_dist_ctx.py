"""repro.dist.ctx edge cases: identity outside a context, unknown-rule
rejection, nesting, and rule fitting on impossible splits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.ctx import current_rules, shard, use_rules
from repro.dist.sharding import activation_rules, data_axes
from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh()


def test_shard_is_identity_outside_context():
    x = jnp.arange(12.0).reshape(3, 4)
    assert current_rules() is None
    y = shard(x, "act_btd")
    assert y is x          # no constraint op, not even a copy


def test_unknown_rule_rejected(mesh):
    x = jnp.ones((2, 4, 8))
    with use_rules(mesh, activation_rules(mesh)):
        with pytest.raises(KeyError, match="no_such_rule"):
            shard(x, "no_such_rule")
    # and the context unwound cleanly despite the raise
    assert current_rules() is None


def test_rank_mismatch_rejected(mesh):
    """Higher-rank arrays than the rule are an error; LOWER-rank arrays
    (flattened-token call sites) squeeze the middle of the spec instead."""
    with use_rules(mesh, activation_rules(mesh)):
        with pytest.raises(ValueError, match="rank"):
            shard(jnp.ones((2, 4, 8, 3, 5)), "act_bthd")
        with pytest.raises(ValueError, match="cannot apply"):
            shard(jnp.ones((6,)), "act_btd")
        y = shard(jnp.ones((4, 8)), "act_btf")   # (T, F) flattened tokens
        assert y.shape == (4, 8)


def test_nested_contexts_restore_outer(mesh):
    outer = {"act_btd": P(None, None, None)}
    inner = {"act_btd": P("data", None, None),
             "extra": P(None)}
    with use_rules(mesh, outer):
        assert current_rules()[1] == outer
        with use_rules(mesh, inner):
            assert current_rules()[1] == inner
            assert set(current_rules()[1]) == {"act_btd", "extra"}
        # inner popped: outer table (without "extra") is active again
        assert current_rules()[1] == outer
        with pytest.raises(KeyError):
            shard(jnp.ones((1,)), "extra")
    assert current_rules() is None


def test_shard_applies_constraint_and_preserves_values(mesh):
    x = jnp.arange(2 * 4 * 8, dtype=jnp.float32).reshape(2, 4, 8)
    with use_rules(mesh, activation_rules(mesh)):
        y = jax.jit(lambda t: shard(t, "act_btd") * 1.0)(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_indivisible_axes_are_dropped(mesh):
    """A rule naming an axis the dim can't honor is relaxed, not an error:
    batch 3 on an n-device data axis only splits when n divides 3."""
    x = jnp.ones((3, 5, 7))
    with use_rules(mesh, activation_rules(mesh)):
        y = shard(x, "act_btd")
    assert y.shape == x.shape


def test_rules_must_cover_model_call_sites(mesh):
    """Every rule name emitted by models/ exists in the table, for every
    placement variant."""
    used_by_models = {"act_btd", "act_bthd", "act_btf", "moe_ecd", "moe_ecf",
                      "moe_gtd", "moe_gecd", "moe_gecf"}
    for cluster in (False, True):
        for tp in (False, True):
            rules = activation_rules(mesh, cluster_vmapped=cluster, tp=tp)
            assert used_by_models <= set(rules)


def test_data_axes_variants():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert data_axes(FakeMesh()) == ("pod", "data")
    assert data_axes(FakeMesh(), cluster_vmapped=True) == ("data",)
    assert data_axes(FakeMesh(), tp=False) == ("pod", "data", "model")

    class TwoAxis:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    assert data_axes(TwoAxis()) == ("data",)
