"""Orbital substrate tests: Walker-Delta geometry, LISL graph, GS windows."""
import numpy as np
import pytest

from repro.constellation.gs import GroundStation, WindowTable
from repro.constellation.lisl import (LISLConfig, earth_blocked, lisl_graph,
                                      distance_matrix)
from repro.constellation.sim import ConstellationEnv
from repro.constellation.walker import R_EARTH, WalkerDelta


class TestWalker:
    def test_geometry_constants(self):
        w = WalkerDelta()
        assert w.n_sats == 720
        assert 90 * 60 < w.period_s < 100 * 60      # LEO ~96 min
        pos = w.positions(0.0)
        assert pos.shape == (720, 3)
        r = np.linalg.norm(pos, axis=-1)
        np.testing.assert_allclose(r, w.radius_m, rtol=1e-9)

    def test_orbit_closes_after_period(self):
        w = WalkerDelta()
        p0 = w.positions(0.0)
        p1 = w.positions(w.period_s)
        np.testing.assert_allclose(p0, p1, atol=1.0)   # meters

    def test_inclination(self):
        """Max |z| = R sin(incl)."""
        w = WalkerDelta()
        ts = np.linspace(0, w.period_s, 50)
        z = np.abs(w.positions(ts)[..., 2]).max()
        expect = w.radius_m * np.sin(np.deg2rad(70.0))
        assert abs(z - expect) / expect < 0.01

    def test_in_plane_spacing(self):
        """20 sats/plane -> 18 deg spacing -> chord 2R sin(9 deg)."""
        w = WalkerDelta()
        pos = w.positions(0.0)
        d01 = np.linalg.norm(pos[0] - pos[1])
        expect = 2 * w.radius_m * np.sin(np.pi / 20)
        assert abs(d01 - expect) / expect < 1e-6


class TestLISL:
    def test_graph_symmetric_and_fanout_capped(self):
        w = WalkerDelta()
        cfg = LISLConfig(range_m=1_500_000, fanout_default=4)
        adj = lisl_graph(w, 0.0, cfg)
        assert (adj == adj.T).all()
        assert not adj.diagonal().any()
        assert adj.sum(1).max() <= 4

    def test_range_monotone(self):
        """Longer LISL range -> more links (paper's 4 range settings)."""
        w = WalkerDelta()
        counts = []
        for rng_km in (659, 1319, 1500, 1700):
            cfg = LISLConfig(range_m=rng_km * 1e3, fanout_default=10)
            counts.append(lisl_graph(w, 0.0, cfg).sum())
        assert counts == sorted(counts)
        assert counts[-1] > counts[0]

    def test_earth_blockage(self):
        """Antipodal satellites are blocked."""
        p1 = np.array([[7e6, 0.0, 0.0]])
        p2 = np.array([[-7e6, 0.0, 0.0]])
        assert earth_blocked(p1, p2)[0]
        p3 = np.array([[7e6, 1e5, 0.0]])
        assert not earth_blocked(p1, p3)[0]


class TestGS:
    def test_visibility_periodic(self):
        w = WalkerDelta()
        gs = GroundStation()
        ts = np.arange(0, 86_400, 60.0)
        pos = w.positions(ts)[:, 0, :]
        vis = gs.visible(pos, ts)
        frac = vis.mean()
        # a LEO sat sees one GS site a few % of the day
        assert 0.0 < frac < 0.2

    def test_window_table_matches_scan(self):
        w = WalkerDelta()
        gs = GroundStation()
        table = WindowTable(gs, w, step_s=60.0, horizon_s=12 * 3600)
        for sat in (0, 100, 371):
            wait_t, dist_t = table.next_window(sat, 0.0)
            wait_s, dist_s = gs.next_window(w, sat, 0.0, step_s=60.0,
                                            horizon_s=12 * 3600)
            assert abs(wait_t - wait_s) <= 60.0
            if np.isfinite(dist_s):
                assert abs(dist_t - dist_s) / dist_s < 0.2

    def test_window_table_wait_measured_from_t0(self):
        """Regression for the wait-bias bug: waits were measured from the
        floored grid index (overestimating every wait by up to step_s) and
        a pass that ended mid-step returned wait=0 with a stale pre-t0
        slant range. Cross-checks the table against the exact
        ``GroundStation.next_window`` scan on grid-aligned queries (same
        sample points -> identical waits) and pins the fixed semantics on
        off-grid queries (contact = FIRST visible grid sample at/after t0,
        wait measured from t0 itself)."""
        w = WalkerDelta()
        gs = GroundStation()
        step, horizon = 60.0, 12 * 3600
        table = WindowTable(gs, w, step_s=step, horizon_s=horizon)
        rng = np.random.default_rng(3)

        for sat in (0, 57, 371, 600):
            # exact agreement with the O(horizon) scan at on-grid t0
            for m in rng.integers(0, 240, 5):
                t0 = float(m) * step
                wait_t, dist_t = table.next_window(sat, t0)
                if t0 + wait_t >= horizon:
                    continue                  # table wrapped; scan didn't
                wait_s, dist_s = gs.next_window(w, sat, t0, step_s=step,
                                                horizon_s=horizon)
                assert wait_t == wait_s
                assert abs(dist_t - dist_s) / dist_s < 1e-5   # f32 table

            # any t0 (off-grid, near the table end -> wrap path, beyond
            # one period): the wait must EXACTLY match the brute-force
            # periodic reference — wait 0 when the samples on both sides
            # of t0 are visible (ongoing pass), else measured from t0 to
            # the first visible grid sample at/after t0
            def ref_wait(sat, t0):
                f, i0 = int(np.floor(t0 / step)), int(np.ceil(t0 / step))
                n = table.n_steps
                if f != i0 and table.vis[f % n, sat] and \
                        table.vis[i0 % n, sat]:
                    return 0.0
                for j in range(i0, i0 + n):
                    if table.vis[j % n, sat]:
                        return j * step - t0
                return float(horizon)

            t0s = [(float(m) + float(rng.uniform(0.05, 0.95))) * step
                   for m in rng.integers(0, 240, 8)]
            t0s += [(table.n_steps - 3 + 0.4) * step,    # forces the wrap
                    (table.n_steps + 51 + 0.7) * step]   # t0 past one period
            for t0 in t0s:
                wait_t, _ = table.next_window(sat, t0)
                assert wait_t == ref_wait(sat, t0)
                if 0.0 < wait_t < horizon:
                    contact = (t0 + wait_t) / step
                    assert abs(contact - round(contact)) < 1e-6  # on grid
                    assert table.vis[int(round(contact)) % table.n_steps,
                                     sat]

    def test_window_table_no_stale_contact_after_pass_end(self):
        """A query landing between the last visible sample of a pass and
        the next (invisible) sample must report the NEXT pass, not wait=0
        with the ended pass's slant range."""
        w = WalkerDelta()
        gs = GroundStation()
        step = 60.0
        table = WindowTable(gs, w, step_s=step, horizon_s=12 * 3600)
        for sat in range(50):
            col = table.vis[:, sat]
            ends = np.flatnonzero(col[:-1] & ~col[1:])   # pass-end samples
            if ends.size:
                break
        assert ends.size > 0
        i = int(ends[0])
        t0 = (i + 0.5) * step                            # just past sample i
        wait, _ = table.next_window(sat, t0)
        assert wait > 0.0                                # pre-fix: == 0.0

        # ...but a query INSIDE an ongoing pass (visible samples on both
        # sides) is in contact now: wait must be exactly 0
        mids = np.flatnonzero(col[:-1] & col[1:])
        assert mids.size > 0
        t0 = (int(mids[0]) + 0.5) * step
        wait, _ = table.next_window(sat, t0)
        assert wait == 0.0

    def test_slant_range_reasonable(self):
        """Contact slant range between altitude and horizon distance."""
        w = WalkerDelta()
        env = ConstellationEnv(n_clients=5, seed=1)
        wait, dist = env.gs_window_wait(0, 0.0)
        assert 570_000 <= dist < 3_000_000


class TestEnv:
    def test_reachability_time_varying(self):
        env = ConstellationEnv(n_clients=20, seed=0)
        a0 = env.client_adjacency(0.0)
        a1 = env.client_adjacency(1800.0)
        assert (a0 != a1).any()            # E_LISL(t) moves

    def test_master_reach_submatrix(self):
        env = ConstellationEnv(n_clients=20, seed=0)
        masters = np.array([0, 5, 10, 15])
        r = env.master_reach(masters, 0.0)
        full = env.client_adjacency(0.0)
        np.testing.assert_array_equal(r, full[np.ix_(masters, masters)])

    def test_lisl_distance_consistent_with_reach(self):
        env = ConstellationEnv(n_clients=15, seed=2)
        adj = env.client_adjacency(0.0)
        for i in range(5):
            for j in range(5):
                d = env.lisl_distance(i, j, 0.0)
                if i == j:
                    assert d == 0.0
                elif adj[i, j]:
                    assert np.isfinite(d) and d > 0
                else:
                    assert np.isinf(d)
