"""Golden parity: the pluggable RoundEngine must reproduce the
pre-refactor ``Session`` / per-baseline loops bit-for-bit at fixed seed.

Two layers of pinning:

* cross-process: tests/golden_engine.json holds the host-side (and hence
  machine-reproducible) EnergyLedger of every algorithm, captured from the
  frozen pre-refactor implementations (tests/golden_capture.py).
* in-process: the frozen pre-refactor loops (tests/reference_impl.py) run
  side-by-side with the engine and the final weights must match
  bit-for-bit (XLA CPU results are only reproducible within one process,
  so weights cannot be pinned in JSON).
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.session import Session
from repro.fl.baselines import BASELINES

from golden_capture import (baseline_config, build_setup, session_config,
                            weights_digest)
from reference_impl import REFERENCE_BASELINES, reference_session_run

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_engine.json")

LEDGER_COUNT_FIELDS = ("intra_lisl_count", "inter_lisl_count", "gs_count")


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def assert_ledger_equal(ledger, want: dict):
    got = dataclasses.asdict(ledger)
    assert set(got) == set(want)
    for k, v in want.items():
        assert got[k] == v, (k, got[k], v)   # bit-for-bit, counts and floats


class TestCroSatFLParity:
    def test_session_matches_reference_and_golden(self, golden):
        env, model = build_setup()
        cfg = session_config(model)
        eval_fn = lambda p, r: model.evaluate(p)   # noqa: E731
        w_eng, led_eng, hist_eng = Session(cfg, env, model).run(
            eval_fn=eval_fn)

        env, model = build_setup()
        w_ref, led_ref, hist_ref = reference_session_run(
            cfg, env, model, eval_fn=eval_fn)

        assert_ledger_equal(led_eng, dataclasses.asdict(led_ref))
        assert_ledger_equal(led_eng, golden["CroSatFL"]["ledger"])
        assert weights_digest(w_eng) == weights_digest(w_ref)
        assert ([h["acc"] for h in hist_eng]
                == [h["acc"] for h in hist_ref])

    def test_skipped_idle_regression(self, golden):
        """Regression pin for the skipped-satellite idle accounting fix:
        pre-fix core/session.py summed the barrier wait over participants
        only; the golden waiting time includes the full-barrier idle of
        every Skip-One'd member and must stay exactly this value.

        (Value re-pinned once when the WindowTable.next_window floor bias
        was fixed — GS waits are now measured from t0, not the floored
        grid point, which trimmed ~10 s of spurious wait from the session;
        the skipped-idle component is unchanged.)"""
        want = golden["CroSatFL"]["ledger"]["waiting_time_s"]
        assert want == 155936.70206156062


class TestBaselineParity:
    @pytest.mark.parametrize("name", list(BASELINES))
    def test_baseline_matches_reference_and_golden(self, name, golden):
        env, model = build_setup()
        cfg = baseline_config(model)
        eval_fn = lambda p, r: model.evaluate(p)   # noqa: E731
        eng = BASELINES[name](cfg, env, model)
        assert eng.name == name
        w_eng, led_eng, hist_eng = eng.run(eval_fn=eval_fn)

        env, model = build_setup()
        ref = REFERENCE_BASELINES[name](cfg, env, model)
        w_ref, led_ref, hist_ref = ref.run(eval_fn=eval_fn)

        assert_ledger_equal(led_eng, dataclasses.asdict(led_ref))
        assert_ledger_equal(led_eng, golden[name]["ledger"])
        assert weights_digest(w_eng) == weights_digest(w_ref)
        assert ([h["acc"] for h in hist_eng]
                == [h["acc"] for h in hist_ref])
