"""Byzantine-robust aggregation + quorum gating (DESIGN.md §14).

Covers the repro.fl.robust estimators as units, the quorum commit gate,
``apply_robustness`` over both merge container types, silent-corruption
determinism across the list and stacked executor paths, the transport
retry-policy overrides, and — via mini_hypothesis/hypothesis — the
permutation-invariance and breakdown-point properties that make
median/trimmed-mean actual defenses where plain averaging is not.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # pragma: no cover - env dep
    from mini_hypothesis import given, settings, strategies as st

from repro.fl.robust import (AGGREGATORS, FedAvgAggregator, KrumAggregator,
                             MedianAggregator, NormClipAggregator,
                             QuorumPolicy, TrimmedMeanAggregator,
                             _lane_finite_mask, apply_robustness,
                             resolve_aggregator, resolve_quorum)

SETTINGS = dict(max_examples=25, deadline=None)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _tree(seed, shape=(3, 2)):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, shape), "b": jnp.zeros(shape[-1:])}


def _lanes(K, seed0=0):
    return _stack([_tree(seed0 + i) for i in range(K)])


class _Sel:
    """RoundSelection stand-in: just the ids/mask the quorum reads."""

    def __init__(self, engaged, trained):
        self.ids = np.arange(engaged)
        self.mask = np.zeros(engaged, bool)
        self.mask[:trained] = True


class _Ctx:
    def __init__(self, robust=None, quorum=None, obs=None):
        self.robust, self.quorum, self.obs = robust, quorum, obs


class _Obs:
    def __init__(self):
        self.rejects, self.quorums = [], []

    def robust_reject(self, kc, reason, **info):
        self.rejects.append((kc, reason))

    def quorum(self, kc, frac, ok):
        self.quorums.append((kc, frac, ok))


class _Model:
    def stack(self, params_list):
        return _stack(params_list)

    def unstack(self, stacked, k):
        return [jax.tree.map(lambda x: x[i], stacked) for i in range(k)]


class _State:
    def __init__(self, cluster_models):
        self.cluster_models = cluster_models


# ---------------------------------------------------------------------------
# aggregator units
# ---------------------------------------------------------------------------

class TestAggregators:
    def test_fedavg_is_identity(self):
        old, new = _lanes(4), _lanes(4, 10)
        agg = FedAvgAggregator()
        assert agg.identity
        assert agg.robustify(old, new, np.ones(4, bool)) is new

    def test_median_broadcasts_consensus(self):
        old, new = _lanes(5), _lanes(5, 10)
        out = MedianAggregator().robustify(old, new, np.ones(5, bool))
        ref = jnp.median(new["w"], axis=0)
        for k in range(5):
            assert np.allclose(out["w"][k], ref)

    def test_median_ignores_invalid_lane(self):
        old, new = _lanes(5), _lanes(5, 10)
        bad = jax.tree.map(lambda l: l.at[2].set(jnp.nan), new)
        mask = _lane_finite_mask(bad, 5)
        assert mask.tolist() == [True, True, False, True, True]
        out = MedianAggregator().robustify(old, bad, mask)
        assert np.isfinite(np.asarray(out["w"])).all()
        ref = jnp.median(new["w"][np.array([0, 1, 3, 4])], axis=0)
        assert np.allclose(out["w"][0], ref)

    def test_all_invalid_falls_back_to_old(self):
        old, new = _lanes(3), _lanes(3, 10)
        none = np.zeros(3, bool)
        for agg in (MedianAggregator(), TrimmedMeanAggregator(),
                    NormClipAggregator(), KrumAggregator()):
            out = agg.robustify(old, new, none)
            assert np.array_equal(np.asarray(out["w"]),
                                  np.asarray(old["w"])), agg.name

    def test_trimmed_mean_drops_extremes(self):
        old = _lanes(5)
        rows = [_tree(i) for i in range(5)]
        rows[0] = jax.tree.map(lambda l: l + 1e6, rows[0])   # poisoned
        new = _stack(rows)
        out = TrimmedMeanAggregator(0.2).robustify(
            old, new, np.ones(5, bool))
        clean = np.stack([np.asarray(r["w"]) for r in rows[1:]])
        assert np.asarray(out["w"]).max() <= clean.max() + 1e-5

    def test_norm_clip_preserves_honest_lanes_and_tames_outlier(self):
        old = _lanes(5)
        rows = [jax.tree.map(lambda l: l + 0.1, _tree(i))
                for i in range(5)]
        rows[3] = jax.tree.map(lambda l: l + 1e4, rows[3])
        new = _stack(rows)
        obs = _Obs()
        out = NormClipAggregator(mult=2.0).robustify(
            old, new, np.ones(5, bool), obs=obs)
        for k in (0, 1, 2, 4):    # honest lanes commit verbatim
            assert np.array_equal(np.asarray(out["w"][k]),
                                  np.asarray(new["w"][k]))
        d_out = float(jnp.linalg.norm(out["w"][3] - old["w"][3]))
        d_in = float(jnp.linalg.norm(new["w"][3] - old["w"][3]))
        assert d_out < d_in / 10
        assert (3, "norm_clip") in obs.rejects

    def test_krum_rejects_outlier(self):
        old = _lanes(5)
        rows = [jax.tree.map(lambda l: l * 0.01, _tree(i))
                for i in range(5)]
        rows[2] = jax.tree.map(lambda l: l + 50.0, rows[2])
        new = _stack(rows)
        obs = _Obs()
        out = KrumAggregator(f=1, m=1).robustify(
            old, new, np.ones(5, bool), obs=obs)
        assert (2, "krum") in obs.rejects
        assert float(np.abs(np.asarray(out["w"])).max()) < 1.0

    def test_registry_and_resolvers(self):
        assert sorted(AGGREGATORS) == ["fedavg", "krum", "median",
                                       "norm_clip", "trimmed_mean"]
        assert resolve_aggregator(None).identity
        agg = MedianAggregator()
        assert resolve_aggregator(agg) is agg
        with pytest.raises(KeyError, match="unknown aggregator"):
            resolve_aggregator("nope")
        with pytest.raises(TypeError):
            resolve_aggregator(3.0)
        with pytest.raises(ValueError):
            TrimmedMeanAggregator(0.5)
        with pytest.raises(ValueError):
            NormClipAggregator(0.0)
        with pytest.raises(ValueError):
            KrumAggregator(m=0)


# ---------------------------------------------------------------------------
# quorum gate
# ---------------------------------------------------------------------------

class TestQuorum:
    def test_fractions(self):
        q = QuorumPolicy(0.5)
        fr = q.fractions([_Sel(2, 2), _Sel(2, 1), _Sel(4, 1), _Sel(0, 0)])
        assert fr.tolist() == [1.0, 0.5, 0.25, 1.0]

    def test_resolve(self):
        assert resolve_quorum(None) is None
        q = resolve_quorum(0.6)
        assert isinstance(q, QuorumPolicy) and q.min_frac == 0.6
        assert resolve_quorum(q) is q
        with pytest.raises(TypeError):
            resolve_quorum(True)
        with pytest.raises(ValueError):
            QuorumPolicy(0.0)

    def test_below_quorum_carries_old_forward(self):
        old, new = _lanes(3), _lanes(3, 10)
        q = QuorumPolicy(0.6)
        ctx = _Ctx(quorum=q, obs=_Obs())
        sels = [_Sel(2, 2), _Sel(2, 1), _Sel(2, 2)]   # cluster 1 at 0.5
        out = apply_robustness(ctx, _Model(), _State(old), new, sels)
        assert np.array_equal(np.asarray(out["w"][1]),
                              np.asarray(old["w"][1]))
        assert np.array_equal(np.asarray(out["w"][0]),
                              np.asarray(new["w"][0]))
        assert q.degraded == 1
        assert (1, 0.5, False) in ctx.obs.quorums

    def test_partial_quorum_reweights_delta(self):
        old, new = _lanes(4), _lanes(4, 10)
        ctx = _Ctx(quorum=QuorumPolicy(0.5), obs=_Obs())
        sels = [_Sel(2, 2), _Sel(4, 3), _Sel(2, 2), _Sel(2, 2)]
        out = apply_robustness(ctx, _Model(), _State(old), new, sels)
        want = old["w"][1] + 0.75 * (new["w"][1] - old["w"][1])
        assert np.allclose(np.asarray(out["w"][1]), np.asarray(want))

    def test_full_quorum_is_verbatim(self):
        old, new = _lanes(3), _lanes(3, 10)
        ctx = _Ctx(quorum=QuorumPolicy(0.5))
        sels = [_Sel(2, 2)] * 3
        out = apply_robustness(ctx, _Model(), _State(old), new, sels)
        assert np.array_equal(np.asarray(out["w"]), np.asarray(new["w"]))


# ---------------------------------------------------------------------------
# apply_robustness plumbing
# ---------------------------------------------------------------------------

class TestApplyRobustness:
    def test_default_path_returns_same_object(self):
        new = _lanes(3, 10)
        ctx = _Ctx(robust=FedAvgAggregator(), quorum=None)
        out = apply_robustness(ctx, _Model(), _State(_lanes(3)), new,
                               [_Sel(2, 2)] * 3)
        assert out is new    # pointer-free early-out: golden bit-parity

    def test_list_and_stacked_agree(self):
        old = _lanes(4)
        rows = [_tree(10 + i) for i in range(4)]
        rows[1] = jax.tree.map(lambda l: jnp.full_like(l, jnp.nan),
                               rows[1])
        sels = [_Sel(2, 2)] * 4
        model = _Model()
        outs = []
        for fresh in (list(rows), _stack(rows)):
            ctx = _Ctx(robust=MedianAggregator(), quorum=QuorumPolicy(0.5),
                       obs=_Obs())
            out = apply_robustness(ctx, model, _State(old), fresh, sels)
            if isinstance(out, list):
                assert len(out) == 4
                out = _stack(out)
            outs.append(np.asarray(out["w"]))
        assert np.array_equal(outs[0], outs[1])

    def test_nonfinite_reject_events(self):
        old = _lanes(3)
        rows = [_tree(10 + i) for i in range(3)]
        rows[2] = jax.tree.map(lambda l: l * jnp.inf, rows[2])
        ctx = _Ctx(robust=TrimmedMeanAggregator(), obs=_Obs())
        apply_robustness(ctx, _Model(), _State(old), _stack(rows),
                         [_Sel(2, 2)] * 3)
        assert (2, "nonfinite") in ctx.obs.rejects


# ---------------------------------------------------------------------------
# properties (hypothesis / mini_hypothesis)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(3, 8), seed=st.integers(0, 100))
def test_permutation_invariance(n, seed):
    """Median/trimmed-mean consensus must not depend on lane order."""
    rng = np.random.default_rng(seed)
    rows = [_tree(int(rng.integers(1000))) for _ in range(n)]
    perm = rng.permutation(n)
    valid = np.ones(n, bool)
    old = _lanes(n)
    for agg in (MedianAggregator(), TrimmedMeanAggregator(0.2)):
        a = agg.robustify(old, _stack(rows), valid)
        b = agg.robustify(old, _stack([rows[i] for i in perm]), valid)
        assert np.array_equal(np.asarray(a["w"][0]),
                              np.asarray(b["w"][0])), agg.name


@settings(**SETTINGS)
@given(n=st.integers(2, 8), seed=st.integers(0, 100))
def test_fedavg_bit_parity_property(n, seed):
    """With no corrupted lanes the fedavg path returns the inputs
    untouched — the exact object, any n, any seed."""
    rows = [_tree(seed + i) for i in range(n)]
    new = _stack(rows)
    ctx = _Ctx(robust=FedAvgAggregator())
    out = apply_robustness(ctx, _Model(), _State(_lanes(n)), new,
                           [_Sel(2, 2)] * n)
    assert out is new


@settings(**SETTINGS)
@given(n=st.integers(3, 9), seed=st.integers(0, 100),
       scale=st.floats(1e3, 1e8))
def test_breakdown_point(n, seed, scale):
    """With f poisoned lanes inside each estimator's tolerance (f < n/2
    for the median, f <= trim count for the trimmed mean), the consensus
    stays inside the honest coordinate envelope; the plain lane mean
    (what FedAvg's cross-aggregation mixes) is dragged out by a single
    poisoned lane."""
    rng = np.random.default_rng(seed)
    base = [_tree(int(rng.integers(1000))) for _ in range(n)]
    trim = TrimmedMeanAggregator(0.34)
    cases = ((MedianAggregator(), (n - 1) // 2),
             (trim, min(int(trim.trim_frac * n), (n - 1) // 2)))
    for agg, f in cases:
        rows = list(base)
        honest = np.stack([np.asarray(r["w"]) for r in rows[f:]])
        lo, hi = honest.min(), honest.max()
        for i in range(f):
            rows[i] = jax.tree.map(lambda l: l + scale, rows[i])
        new, valid, old = _stack(rows), np.ones(n, bool), _lanes(n)
        out = np.asarray(agg.robustify(old, new, valid)["w"][0])
        assert out.min() >= lo - 1e-4 and out.max() <= hi + 1e-4, agg.name
        if f:   # the undefended average has breakdown point 0
            assert float(jnp.mean(new["w"], axis=0).max()) > hi + 1.0


# ---------------------------------------------------------------------------
# silent corruption: injector mechanics + schedule generators
# ---------------------------------------------------------------------------

class TestSilentCorruption:
    def _pending(self, mode, cluster=1, seed=7):
        return {"cluster": cluster, "mode": mode, "scale": 100.0,
                "seed": seed}

    @pytest.mark.parametrize("mode", ["sign_flip", "large_scale",
                                      "nan_splat", "bit_noise"])
    def test_list_and_stacked_corruption_agree(self, mode):
        from repro.faults import FaultSchedule, as_injector

        # lanes big enough that the 1% bit_noise mode certainly flips
        # something (P(no flip) ~ 0.99^2048)
        rows = [_tree(20 + i, shape=(64, 32)) for i in range(4)]
        sels = [_Sel(2, 2)] * 4
        outs = []
        for fresh in (list(rows), _stack(rows)):
            inj = as_injector(FaultSchedule())
            inj.state.silent_pending.append(self._pending(mode))
            out = inj.corrupt_result(_Ctx(), _Model(), fresh, sels)
            if isinstance(out, list):
                out = _stack(out)
            outs.append(np.asarray(out["w"]))
        if mode == "nan_splat":
            assert np.isnan(outs[0][1]).all() and np.isnan(outs[1][1]).all()
            assert np.isfinite(outs[0][0]).all()
        else:
            assert np.array_equal(outs[0], outs[1])
            assert not np.array_equal(outs[0][1], np.asarray(rows[1]["w"]))
            # untargeted lanes untouched, bit-for-bit
            assert np.array_equal(outs[0][0], np.asarray(rows[0]["w"]))

    def test_corruption_consumes_pending_and_spares_input(self):
        from repro.faults import FaultSchedule, as_injector

        rows = [_tree(30 + i) for i in range(3)]
        keep = np.asarray(rows[0]["w"]).copy()
        inj = as_injector(FaultSchedule())
        inj.state.silent_pending.append(self._pending("sign_flip",
                                                      cluster=0))
        out = inj.corrupt_result(_Ctx(), _Model(), list(rows),
                                 [_Sel(2, 2)] * 3)
        assert inj.state.silent_pending == []
        assert np.array_equal(np.asarray(rows[0]["w"]), keep)
        assert np.array_equal(np.asarray(out[0]["w"]), -keep)

    def test_state_roundtrip_carries_pending(self):
        from repro.faults.model import FaultState

        fs = FaultState()
        fs.silent_pending.append(self._pending("bit_noise"))
        fs2 = FaultState()
        fs2.load(fs.to_dict())
        assert fs2.silent_pending == fs.silent_pending
        fs2.reset()
        assert fs2.silent_pending == []

    def test_poisson_silent_family(self):
        from repro.faults import FaultSchedule, SilentCorruption

        a = FaultSchedule.poisson(4000.0, seed=3, n_clusters=4,
                                  silent_rate_per_h=20.0)
        b = FaultSchedule.poisson(4000.0, seed=3, n_clusters=4,
                                  silent_rate_per_h=20.0)
        assert a.faults == b.faults    # pure function of the arguments
        silent = [f for f in a.faults if isinstance(f, SilentCorruption)]
        assert silent and all(f.mode in ("sign_flip", "large_scale",
                                         "nan_splat", "bit_noise")
                              for f in silent)
        none = FaultSchedule.poisson(4000.0, seed=3, n_clusters=4)
        assert not any(isinstance(f, SilentCorruption)
                       for f in none.faults)

    def test_gilbert_elliott_silent_mode(self):
        from repro.faults import FaultSchedule, LinkOutage, SilentCorruption

        sch = FaultSchedule.gilbert_elliott(
            2000.0, seed=1, p_g2b=0.4, mode="silent",
            corrupt_mode="bit_noise")
        kinds = {type(f) for f in sch.faults}
        assert kinds == {SilentCorruption}
        out = FaultSchedule.gilbert_elliott(2000.0, seed=1, p_g2b=0.4)
        assert {type(f) for f in out.faults} == {LinkOutage}
        with pytest.raises(ValueError):
            FaultSchedule.gilbert_elliott(100.0, mode="nope")

    def test_trace_events_validate(self):
        from repro.obs import TracingObserver
        from repro.obs.trace import validate_event

        obs = TracingObserver()
        obs.robust_reject(2, "nonfinite")
        obs.robust_reject(None, "norm_clip", norm=3.0, thresh=1.0)
        obs.quorum(1, 0.5, False)
        obs.quorum(0, 1.0, True)
        for ev in obs.tracer.events:
            assert validate_event(ev) == [], ev
        assert obs.metrics.total("robust_rejects") == 2
        assert obs.metrics.total("quorum_degraded") == 1


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

class TestEngineIntegration:
    def test_golden_parity_with_explicit_fedavg(self):
        from golden_capture import build_setup, session_config
        from repro.core.session import Session

        golden = json.load(open(os.path.join(os.path.dirname(__file__),
                                             "golden_engine.json")))
        env, model = build_setup()
        cfg = dataclasses.replace(session_config(model),
                                  aggregator="fedavg")
        _, led, _ = Session(cfg, env, model).run()
        assert dataclasses.asdict(led) == golden["CroSatFL"]["ledger"]

    def test_retry_overrides_reach_fault_state(self):
        from repro.faults import FaultSchedule
        from repro.faults.chaos import build_engine, tiny_setup

        env, model = tiny_setup()
        eng = build_engine("CroSatFL", env, model, rounds=1,
                           faults=FaultSchedule())
        assert eng.faults.state.backoff0_s == 30.0       # schedule default
        assert eng.faults.state.max_retries == 4
        import repro.fl.engine as fe
        cfg = fe.EngineConfig(rounds=1, local_epochs=1, c_flop=5e7,
                              model_bits=model.model_bits(),
                              retry_base_s=5.0, retry_max_attempts=9)
        eng2 = fe.make_crosatfl(cfg, env, model, faults=FaultSchedule())
        assert eng2.faults.state.backoff0_s == 5.0
        assert eng2.faults.state.max_retries == 9
        eng2.faults.state.reset()          # bind()'s reset must not undo it
        assert eng2.faults.state.backoff0_s == 5.0
        assert eng2.faults.state.max_retries == 9

    def test_fedavg_poisoned_median_survives(self):
        from repro.faults import corruption_schedule
        from repro.faults.chaos import build_engine, tiny_setup

        env, model = tiny_setup()
        models = {}
        for agg in ("fedavg", "median"):
            eng = build_engine("CroSatFL", env, model, rounds=2,
                               faults=corruption_schedule(),
                               aggregator=agg, quorum=0.6)
            models[agg], _, _ = eng.run()
            if agg == "median":
                assert eng.quorum.degraded >= 1
        fed = np.concatenate([np.asarray(l).ravel() for l in
                              jax.tree.leaves(models["fedavg"])])
        med = np.concatenate([np.asarray(l).ravel() for l in
                              jax.tree.leaves(models["median"])])
        assert not np.isfinite(fed).all()    # NaN lane spread undefended
        assert np.isfinite(med).all()        # consensus filtered it
