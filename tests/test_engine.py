"""Unit tests for the pluggable round engine: transport/codec accounting,
the uniform train/idle rule, measured-cost resolution, and policy
composability (a new FL variant is a policy quadruple, not a new loop)."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.energy import EnergyLedger, LinkParams, e_gs, e_lisl, t_gs
from repro.core.skipone import SkipOneParams
from repro.core.starmask import StarMaskParams
from repro.fl.engine import (AllParticipate, BlockMinifloatCodec,
                             CrossAggMixing, EngineConfig, IdentityCodec,
                             RoundEngine, RoundSelection, StarMaskClustering,
                             Transport, resolve_c_flop)
from repro.fl.engine import costs
from repro.fl.engine.base import EngineContext
from repro.fl.engine.pacing import _charge_train

from golden_capture import build_setup


class TestTransport:
    def test_gs_message_accounting(self):
        led = EnergyLedger()
        lp = LinkParams()
        tr = Transport(led, lp, model_bits=1e6)
        tr.gs(2, 5e5)
        assert led.gs_count == 2
        assert led.gs_energy_j == 2 * e_gs(1e6, lp.gs_rate, 5e5, lp)
        assert led.transmission_time_s == 2 * t_gs(1e6, lp.gs_rate, 5e5, lp)

    def test_codec_scales_payload_not_accounting_shape(self):
        lp = LinkParams()
        led_full, led_mini = EnergyLedger(), EnergyLedger()
        Transport(led_full, lp, 1e6).intra(3, 1e6)
        codec = BlockMinifloatCodec(bits=8)
        Transport(led_mini, lp, 1e6, codec).intra(3, 1e6)
        assert led_mini.intra_lisl_count == led_full.intra_lisl_count == 3
        assert led_mini.lisl_energy_j < led_full.lisl_energy_j
        assert led_mini.lisl_energy_j == 3 * e_lisl(1e6 * 8 / 32,
                                                    lp.lisl_rate, 1e6, lp)
        assert codec.arith_scale == 0.5
        assert IdentityCodec().arith_scale == 1.0


class TestUniformAccounting:
    def _ctx(self, et_full, codec=None):
        led = EnergyLedger()
        return EngineContext(
            cfg=EngineConfig(), env=None, model=None,
            transport=Transport(led, LinkParams(), 1e6, codec),
            rng=np.random.default_rng(0), tt_full=np.zeros(0),
            et_full=et_full, hw_penalty=np.zeros(0))

    def test_skipped_member_idles_full_barrier(self):
        """The regression the refactor fixes at the rule level: a
        Skip-One'd member does no work and waits the whole barrier."""
        ctx = self._ctx(np.array([1.0, 2.0, 4.0]))
        sel = RoundSelection(ids=np.array([0, 1, 2]),
                             mask=np.array([True, True, False]),
                             tt_r=np.array([3.0, 5.0, 100.0]))
        barrier = _charge_train(ctx, sel, None)
        assert barrier == 5.0
        assert ctx.ledger.train_energy_j == 3.0          # skipped id 2 free
        # participant 0 idles 5-3=2s; skipped member idles the 5s barrier
        assert ctx.ledger.waiting_time_s == 2.0 + 5.0

    def test_arith_scale_applies_to_train_energy(self):
        ctx = self._ctx(np.array([8.0]), codec=BlockMinifloatCodec())
        sel = RoundSelection(np.array([0]), np.array([True]),
                             np.array([2.0]))
        _charge_train(ctx, sel, None)
        assert ctx.ledger.train_energy_j == 8.0 * 0.5


class TestMeasuredCost:
    def test_numeric_passthrough(self):
        cfg = EngineConfig(c_flop=123.0)
        assert resolve_c_flop(cfg) is cfg

    def test_bad_spec_rejected(self):
        with pytest.raises(ValueError):
            resolve_c_flop(EngineConfig(c_flop="flops:lots"))

    def test_resolves_from_dryrun_jsonl(self, tmp_path, monkeypatch):
        results = tmp_path / "results"
        results.mkdir()
        row = {"arch": "gemma3-1b", "shape": "train_4k", "status": "ok",
               "flops": 2.56e16}
        (results / "dryrun.jsonl").write_text(json.dumps(row) + "\n")
        monkeypatch.setattr(costs, "_CACHE",
                            str(results / "measured_cflop.json"))
        cfg = resolve_c_flop(
            EngineConfig(c_flop="measured:gemma3-1b/train_4k"))
        assert cfg.c_flop == 2.56e16 / 256          # train_4k global batch
        # second resolution hits the on-disk cache
        cache = json.loads((results / "measured_cflop.json").read_text())
        assert cache["gemma3-1b/train_4k"]["source"] == "dryrun-jsonl"
        cfg2 = resolve_c_flop(
            EngineConfig(c_flop="measured:gemma3-1b/train_4k"))
        assert cfg2.c_flop == cfg.c_flop

    def test_saved_dryrun_row_upgrades_cached_probe(self, tmp_path,
                                                    monkeypatch):
        """Regression (ROADMAP's 'gemma cell falls back to the
        reduced-probe estimate'): once a dry-run row is persisted to
        results/ (launch.dryrun --json writes there by default), it must
        replace a previously cached probe ESTIMATE instead of the stale
        estimate winning forever."""
        results = tmp_path / "results"
        results.mkdir()
        cache_path = results / "measured_cflop.json"
        monkeypatch.setattr(costs, "_CACHE", str(cache_path))
        cache_path.write_text(json.dumps(
            {"gemma3-1b/train_4k": {"c_flop": 1.0,
                                    "source": "reduced-probe"}}))
        # no row on disk yet: the cached estimate still answers
        cfg = resolve_c_flop(
            EngineConfig(c_flop="measured:gemma3-1b/train_4k"))
        assert cfg.c_flop == 1.0
        # a dry run lands; the next resolution upgrades value AND cache
        row = {"arch": "gemma3-1b", "shape": "train_4k", "status": "ok",
               "flops": 2.56e16}
        (results / "dryrun.jsonl").write_text(json.dumps(row) + "\n")
        cfg2 = resolve_c_flop(
            EngineConfig(c_flop="measured:gemma3-1b/train_4k"))
        assert cfg2.c_flop == 2.56e16 / 256
        cache = json.loads(cache_path.read_text())
        assert cache["gemma3-1b/train_4k"]["source"] == "dryrun-jsonl"


class TestComposability:
    def test_new_variant_is_a_policy_quadruple(self):
        """CroSatFL-sans-Skip-One — a variant the paper never names —
        composes from stock policies with no new loop code."""
        env, model = build_setup()
        eng = RoundEngine(
            EngineConfig(rounds=1, local_epochs=1,
                         model_bits=model.model_bits()),
            env, model,
            clustering=StarMaskClustering(StarMaskParams(k_max=4, m_min=2)),
            selection=AllParticipate(),
            mixing=CrossAggMixing(k_nbr=2),
            name="CroSatFL-noskip")
        w, ledger, _ = eng.run()
        assert ledger.gs_count == 2 * 4 or ledger.gs_count > 0
        assert ledger.train_energy_j > 0
        # all-participation: nobody skipped, so per-cluster waiting is only
        # participants' early-finish idle (strictly below one barrier each)
        assert np.isfinite(ledger.waiting_time_s)
