"""Property-based tests on system invariants: real ``hypothesis`` when
installed, otherwise the deterministic tests/mini_hypothesis.py shim
(same API subset, boundary-first seeded draws) so these invariants run
everywhere instead of silently skipping."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # pragma: no cover - env dep
    from mini_hypothesis import given, settings, strategies as st

from repro.core import crossagg, skipone
from repro.data.synth import dirichlet_partition, iid_partition
from repro.kernels.quant import int8_dequantize_ref, int8_quantize_ref

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Skip-One fairness invariants (Eq. 26, 31)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(2, 12), seed=st.integers(0, 1000),
       rounds=st.integers(1, 30))
def test_skipone_invariants(n, seed, rounds):
    rng = np.random.default_rng(seed)
    p = skipone.SkipOneParams()
    state = skipone.SkipOneState.init(n)
    skip_streak = np.zeros(n, int)
    for r in range(rounds):
        tt = rng.lognormal(1, 1, n)
        ee = rng.lognormal(1, 0.5, n)
        mask, state = skipone.select(tt, ee, rng.random(n), state, p, r)
        # |S_k(r)| <= 1 (Eq. 26)
        assert (~mask).sum() <= 1
        # staleness bounded: nobody skipped more than tau_max consecutive
        skip_streak = np.where(mask, 0, skip_streak + 1)
        assert skip_streak.max() <= p.tau_max
        # cooldown counters never negative
        assert (state.kappa >= 0).all()


@settings(**SETTINGS)
@given(n=st.integers(2, 10), seed=st.integers(0, 100))
def test_skipone_barrier_monotone(n, seed):
    """Skipping never increases the cluster barrier (Eq. 28)."""
    rng = np.random.default_rng(seed)
    p = skipone.SkipOneParams()
    tt = rng.lognormal(1, 1, n)
    mask, _ = skipone.select(tt, rng.lognormal(1, 0.5, n), np.zeros(n),
                             skipone.SkipOneState.init(n), p, 0)
    assert tt[mask].max() <= tt.max()


# ---------------------------------------------------------------------------
# Random-k mixing invariants (Eq. 35-37)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(k=st.integers(2, 16), k_nbr=st.integers(1, 5),
       seed=st.integers(0, 1000), density=st.floats(0.0, 1.0))
def test_mixing_matrix_invariants(k, k_nbr, seed, density):
    rng = np.random.default_rng(seed)
    reach = rng.random((k, k)) < density
    n = rng.uniform(1, 100, k)
    groups = crossagg.sample_groups(reach, k_nbr, rng)
    M = crossagg.mixing_matrix(groups, n)
    np.testing.assert_allclose(M.sum(1), 1.0, atol=1e-12)
    assert (M >= 0).all()
    assert (np.diag(M) > 0).all()           # self always included
    # sample-size proportionality within a group (Eq. 37)
    for kk, g in enumerate(groups):
        w = n[g] / n[g].sum()
        np.testing.assert_allclose(M[kk, g], w, atol=1e-12)


@settings(**SETTINGS)
@given(k=st.integers(2, 12), k_nbr=st.integers(0, 5),
       seed=st.integers(0, 1000), density=st.floats(0.0, 1.0))
def test_mixing_matrix_jax_matches_host_semantics(k, k_nbr, seed, density):
    """The jittable Gumbel-top-k path (Eq. 35-37 in one shot) must agree
    with the host path's semantics for any reach mask: row-stochastic,
    reachability-respecting, N_j-proportional within the chosen group, and
    the same group SIZES as sample_groups+mixing_matrix (take-all when a
    row has fewer than k_nbr neighbors) — the members themselves differ
    only by RNG."""
    rng = np.random.default_rng(seed)
    reach = rng.random((k, k)) < density
    n = rng.uniform(1.0, 100.0, k)
    M = np.asarray(crossagg.mixing_matrix_jax(
        jnp.asarray(reach), jnp.asarray(n), k_nbr,
        jax.random.PRNGKey(seed)), np.float64)

    np.testing.assert_allclose(M.sum(1), 1.0, atol=1e-5)     # f32 rows
    assert (M >= 0).all()
    assert (np.diag(M) > 0).all()                 # self always included
    cand = reach & ~np.eye(k, dtype=bool)
    assert not M[~(cand | np.eye(k, dtype=bool))].any()   # reachability

    groups = crossagg.sample_groups(reach, k_nbr, rng)
    for kk in range(k):
        chosen = np.flatnonzero(M[kk] > 0)
        # group-size semantics match the host sampler exactly
        assert chosen.size == 1 + min(k_nbr, int(cand[kk].sum()))
        assert chosen.size == len(groups[kk])
        # Eq. 37 sample-size proportionality over the chosen group
        np.testing.assert_allclose(M[kk, chosen],
                                   n[chosen] / n[chosen].sum(), rtol=1e-5)


@settings(**SETTINGS)
@given(k=st.integers(2, 8), seed=st.integers(0, 500))
def test_mixing_preserves_weighted_mean(k, seed):
    """Data-weighted global mean is invariant under SYMMETRIC group mixing
    (pairwise gossip); the final consolidation recovers it exactly."""
    rng = np.random.default_rng(seed)
    n = rng.uniform(1, 10, k)
    x = rng.normal(size=(k, 4))
    target = (n[:, None] / n.sum() * x).sum(0)
    # symmetric pairwise exchange: both partners mix the same group
    pairs = rng.permutation(k)
    M = np.eye(k)
    for i in range(0, k - 1, 2):
        a, b = pairs[i], pairs[i + 1]
        w = n[[a, b]] / n[[a, b]].sum()
        M[a, [a, b]] = w
        M[b, [a, b]] = w
    x2 = M @ x
    got = (n[:, None] / n.sum() * x2).sum(0)
    np.testing.assert_allclose(got, target, atol=1e-10)


# ---------------------------------------------------------------------------
# Data partitioner
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n_clients=st.integers(2, 20), alpha=st.floats(0.05, 10.0),
       seed=st.integers(0, 100))
def test_dirichlet_partition_is_partition(n_clients, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, 2000)
    parts = dirichlet_partition(labels, n_clients, alpha, seed, min_size=4)
    all_idx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(all_idx, np.arange(2000))
    assert min(len(p) for p in parts) >= 4


def test_dirichlet_more_skewed_than_iid():
    labels = np.random.default_rng(0).integers(0, 10, 5000)
    parts_noniid = dirichlet_partition(labels, 10, alpha=0.5, seed=1)
    parts_iid = iid_partition(5000, 10, seed=1)

    def label_skew(parts):
        dists = []
        for p in parts:
            h = np.bincount(labels[p], minlength=10) / len(p)
            dists.append(h)
        return np.std(dists, axis=0).mean()

    assert label_skew(parts_noniid) > 2 * label_skew(parts_iid)


# ---------------------------------------------------------------------------
# Quantization error bound
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(1, 5000), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 100))
def test_int8_error_bound(n, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, n).astype(np.float32))
    q, s = int8_quantize_ref(x)
    xd = int8_dequantize_ref(q, s, n=n, shape=(n,), dtype=jnp.float32)
    # per-chunk bound: |err| <= scale_chunk / 2, scale_chunk <= absmax/127
    assert float(jnp.abs(xd - x).max()) <= float(jnp.abs(x).max()) / 127.0 + 1e-9


# ---------------------------------------------------------------------------
# Checkpoint roundtrip
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_ckpt_roundtrip(tmp_path_factory, seed):
    from repro.ckpt import load_pytree, save_pytree
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(7, 3)).astype(np.float32)),
            "nested": {"b": jnp.asarray(rng.integers(0, 100, 5)),
                       "c": [jnp.ones(2), jnp.zeros(4)]}}
    path = str(tmp_path_factory.mktemp("ck") / "t.npz")
    save_pytree(tree, path)
    out = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
