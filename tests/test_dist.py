"""Distribution-layer tests on the single real CPU device: sharding specs
are valid, the fl_train/serve steps run, Skip-One mask semantics hold.
(The 512-device production meshes are exercised by launch/dryrun.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.dist.sharding import (activation_rules, batch_specs,
                                 cache_specs_sharding, param_specs)
from repro.launch import steps as S
from repro.launch.mesh import make_test_mesh
from repro.models import api


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh()


class TestShardingSpecs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_param_specs_divisible(self, arch):
        """Every model-axis assignment divides the dim on the 16x16 mesh
        (checked symbolically; no devices needed)."""
        import jax.sharding as shd

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        cfg = get_config(arch)
        tree = api.param_specs(cfg)
        specs = param_specs(tree, FakeMesh(), cfg=cfg)

        def check(leaf, spec):
            for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 9):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                n = int(np.prod([FakeMesh.shape[a] for a in axes]))
                assert dim % n == 0, (arch, leaf.shape, spec)

        jax.tree.map(check, tree, specs)

    @pytest.mark.parametrize("arch", ["gemma3-1b", "granite-34b",
                                      "deepseek-v2-236b"])
    def test_attention_sharded_across_whole_heads(self, arch):
        """The head-quantum rule: wk/wv never split inside head_dim."""
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        cfg = get_config(arch)
        tree = api.param_specs(cfg)
        specs = param_specs(tree, FakeMesh(), cfg=cfg)
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        for path, spec in flat:
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if name in ("wk", "wv") and "model" in str(spec):
                n_units = cfg.num_kv_heads
                assert n_units % 16 == 0, (arch, name, spec)

    def test_cache_specs_long_context_seq_sharded(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        cfg = get_config("deepseek-v2-236b")
        cache = api.cache_specs(cfg, batch=1, max_seq=524_288)
        specs = cache_specs_sharding(cache, FakeMesh())
        found_seq_shard = any("data" in str(s) for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert found_seq_shard


class TestSteps:
    def test_fl_train_step_runs(self, mesh):
        cfg = get_config("stablelm-3b").reduced()
        params = api.init(cfg, jax.random.PRNGKey(0))
        mom = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        B, Sq = 4, 16
        batch = {"tokens": jnp.ones((B, Sq), jnp.int32),
                 "labels": jnp.ones((B, Sq), jnp.int32),
                 "weights": jnp.ones((B,), jnp.float32)}
        step = S.build_fl_train_step(cfg, mesh, clustered=False, lr=0.1)
        with mesh:
            p2, m2, loss = jax.jit(step)(params, mom, batch)
        assert jnp.isfinite(loss)
        # params actually moved
        delta = max(float(jnp.abs(a.astype(jnp.float32) -
                                  b.astype(jnp.float32)).max())
                    for a, b in zip(jax.tree.leaves(p2),
                                    jax.tree.leaves(params)))
        assert delta > 0

    def test_skip_mask_zero_weight_removes_influence(self, mesh):
        """A zero-weighted (skipped) client shard does not affect grads."""
        cfg = get_config("stablelm-3b").reduced()
        params = api.init(cfg, jax.random.PRNGKey(0))
        mom = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        B, Sq = 4, 16
        tok = jax.random.randint(jax.random.PRNGKey(1), (B, Sq), 0,
                                 cfg.vocab_size)
        step = S.build_fl_train_step(cfg, mesh, clustered=False, lr=0.1)
        w_skip = jnp.array([1, 1, 1, 0], jnp.float32)
        b1 = {"tokens": tok, "labels": tok, "weights": w_skip}
        # corrupt the skipped client's shard: result must be identical
        tok2 = tok.at[3].set((tok[3] + 7) % cfg.vocab_size)
        b2 = {"tokens": tok2, "labels": tok2, "weights": w_skip}
        with mesh:
            p1, _, l1 = jax.jit(step)(params, mom, b1)
            p2, _, l2 = jax.jit(step)(params, mom, b2)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
        # embedding rows touched by the corrupt shard differ, but the
        # aggregate LOSS and non-embedding params must agree
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(p1)[0],
                jax.tree_util.tree_flatten_with_path(p2)[0]):
            if "embed" in str(path):
                continue
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-3)

    def test_clustered_step_mixing(self):
        """K=2 clusters with an averaging mix matrix -> identical models."""
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(multi_pod=True)
        cfg = get_config("xlstm-125m").reduced()
        p1 = api.init(cfg, jax.random.PRNGKey(0))
        p2 = api.init(cfg, jax.random.PRNGKey(1))
        params = jax.tree.map(lambda a, b: jnp.stack([a, b]), p1, p2)
        mom = jax.tree.map(lambda p: jnp.zeros_like(p), params)
        K, B, Sq = 2, 2, 16
        batch = {"tokens": jnp.ones((K, B, Sq), jnp.int32),
                 "labels": jnp.ones((K, B, Sq), jnp.int32),
                 "weights": jnp.ones((K, B), jnp.float32)}
        M = jnp.full((2, 2), 0.5, jnp.float32)
        step = S.build_fl_train_step(cfg, mesh, clustered=True, lr=0.01)
        with mesh:
            pm, _, losses = jax.jit(step)(params, mom, batch, M)
        assert losses.shape == (K,)
        for leaf in jax.tree.leaves(pm):
            np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                       np.asarray(leaf[1], np.float32),
                                       atol=1e-3)

    def test_consolidate_step_eq38(self):
        params = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
        out = S.consolidate_step(params, jnp.asarray([1.0, 3.0]))
        np.testing.assert_allclose(np.asarray(out["w"]), [2.5, 3.5])

    def test_serve_steps_run(self, mesh):
        cfg = get_config("gemma3-1b").reduced()
        params = api.init(cfg, jax.random.PRNGKey(0))
        B, Sq = 2, 16
        batch = {"tokens": jnp.ones((B, Sq), jnp.int32)}
        pf = S.build_prefill_step(cfg, mesh)
        with mesh:
            logits = jax.jit(pf)(params, batch)
        assert logits.shape == (B, cfg.vocab_size)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             api.cache_specs(cfg, B, Sq))
        dec = S.build_decode_step(cfg, mesh)
        db = {"token": jnp.ones((B, 1), jnp.int32),
              "pos": jnp.zeros((B,), jnp.int32), "cache": cache}
        with mesh:
            logits2, _ = jax.jit(dec)(params, db)
        assert logits2.shape == (B, cfg.vocab_size)


class TestHLOCost:
    def test_trip_count_parsing(self):
        from repro.launch.hlo_cost import parse_hlo, _trip_count
        hlo = """
HloModule test

%cond.1 (arg: (s32[], f32[4])) -> pred[] {
  %arg = (s32[], f32[4]) parameter(0)
  %gte = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(17)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body.1 (arg: (s32[], f32[4])) -> (s32[], f32[4]) {
  %arg = (s32[], f32[4]) parameter(0)
  ROOT %t = (s32[], f32[4]) tuple(%arg)
}

ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4] parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[4]) tuple(%c0, %p)
  %w = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[4] get-tuple-element(%w), index=1
}
"""
        comps, entry = parse_hlo(hlo)
        assert entry == "%main"
        assert _trip_count(comps["%cond.1"]) == 17

    def test_dot_flops(self):
        from repro.launch.hlo_cost import analyze_hlo
        hlo = """
HloModule test

ENTRY %main (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {
  %a = f32[8,16] parameter(0)
  %b = f32[16,4] parameter(1)
  ROOT %d = f32[8,4] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
        t = analyze_hlo(hlo)
        assert t.flops == 2 * 8 * 4 * 16
