"""Subprocess body for TestShardedMultiDevice (test_batched_exec.py).

Run under a forced 8-device host platform:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \
        PYTHONPATH=src:tests python tests/sharded_check.py

Validates, on the golden fixture, that the sharded executor (a) builds a
real multi-device pod mesh, (b) actually places the stacked cluster
models with a leading "pod" sharding, and (c) reproduces the batched
executor's ledger bit-for-bit and its weights within tolerance. Lives
outside the pytest process because tests/conftest.py deliberately sets
no XLA_FLAGS (single-device parity runs).
"""
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from golden_capture import build_setup, session_config  # noqa: E402

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_engine.json")
TOL = dict(atol=2e-4, rtol=2e-4)


def run(executor: str):
    from repro.fl.engine import make_crosatfl
    env, model = build_setup()
    scfg = session_config(model)
    cfg = dataclasses.replace(scfg.engine_config(), executor=executor)
    eng = make_crosatfl(cfg, env, model, k_nbr=scfg.k_nbr,
                        starmask=scfg.starmask)
    w, ledger, _ = eng.run()
    return eng, w, dataclasses.asdict(ledger)


def main() -> int:
    n_dev = len(jax.devices())
    assert n_dev == 8, f"expected 8 forced host devices, got {n_dev}"

    _, w_b, led_b = run("batched")
    eng, w_s, led_s = run("sharded")

    ex = eng.executor
    assert ex.mesh is not None and ex.mesh.shape["pod"] > 1, \
        f"pod mesh did not span devices: {ex.mesh}"
    pl = ex.last_placement
    assert isinstance(pl, NamedSharding), f"no recorded placement: {pl!r}"
    assert pl.spec and pl.spec[0] == "pod", \
        f"stacked models not pod-sharded: {pl.spec}"

    assert led_s == led_b, "sharded ledger drifted from batched"
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert led_s == golden["CroSatFL"]["ledger"], \
        "sharded ledger drifted from golden"
    for a, b in zip(jax.tree.leaves(w_s), jax.tree.leaves(w_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **TOL)
    print(f"PASS pod={ex.mesh.shape['pod']} devices={n_dev}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
