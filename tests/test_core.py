"""Unit tests for the paper's core modules (StarMask / Skip-One /
cross-aggregation / energy model)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import crossagg, skipone
from repro.core.energy import (CPU, GPU, EnergyLedger, HardwareProfile,
                               LinkParams, e_gs, e_lisl, e_train, t_comp,
                               t_lisl, t_train)
from repro.core.starmask import (Instance, PartialPartition, StarMaskParams,
                                 cluster, effective_capacity, greedy_fallback,
                                 k_min, reward)


def make_instance(n=20, seed=0, fan_lo=3, fan_hi=8):
    rng = np.random.default_rng(seed)
    return Instance(
        share=rng.dirichlet(np.ones(n)),
        hw=rng.integers(0, 2, n),
        t_comp=rng.lognormal(2.0, 0.6, n),
        e_train=rng.lognormal(4.0, 0.5, n),
        fanout=rng.integers(fan_lo, fan_hi, n),
        lisl_e=rng.uniform(1, 5, (n, n)),
    )


# ---------------------------------------------------------------------------
# StarMask
# ---------------------------------------------------------------------------

class TestStarMask:
    def test_cluster_produces_partition(self):
        inst = make_instance(30)
        p = StarMaskParams(k_max=10, m_min=2)
        res = cluster(inst, p, jax.random.PRNGKey(0), n_samples=4)
        assert res.feasible
        got = np.sort(np.concatenate(res.clusters))
        np.testing.assert_array_equal(got, np.arange(30))

    def test_fanout_constraint_eq23(self):
        """|C_k| - 1 <= max effective capacity of members."""
        inst = make_instance(30)
        p = StarMaskParams(k_max=10, m_min=2)
        res = cluster(inst, p, jax.random.PRNGKey(1), n_samples=4)
        cap = effective_capacity(inst, p)
        for c in res.clusters:
            assert len(c) - 1 <= cap[c].max()

    def test_action_mask_blocks_full_cluster(self):
        inst = make_instance(10, fan_lo=2, fan_hi=3)
        p = StarMaskParams(k_max=4, m_min=1)
        pp = PartialPartition(inst, p)
        # fill cluster 0 to capacity
        pp.apply(0, p.k_max)   # open new
        cap0 = pp.cluster_capacity(0)
        t = 1
        while len(pp.members[0]) < cap0 and t < inst.n:
            mask = pp.feasible_actions(t)
            if not mask[0]:
                break
            pp.apply(t, 0)
            t += 1
        mask = pp.feasible_actions(t)
        new_cap = int(max(max(pp.cap[pp.members[0]]), pp.cap[t]) + 1)
        if len(pp.members[0]) + 1 > new_cap:
            assert not mask[0]

    def test_opennew_masked_at_kmax(self):
        inst = make_instance(12)
        p = StarMaskParams(k_max=2, m_min=1)
        pp = PartialPartition(inst, p)
        pp.apply(0, p.k_max)
        pp.apply(1, p.k_max)
        mask = pp.feasible_actions(2)
        assert not mask[p.k_max]

    def test_hw_homogeneous_flag(self):
        inst = make_instance(20)
        p = StarMaskParams(k_max=10, m_min=1, hw_homogeneous=True)
        res = cluster(inst, p, jax.random.PRNGKey(2), n_samples=4)
        if res.feasible:
            for c in res.clusters:
                assert len(set(inst.hw[c])) == 1

    def test_k_min_lower_bound(self):
        inst = make_instance(30)
        p = StarMaskParams()
        km = k_min(inst, p)
        cap = np.sort(effective_capacity(inst, p))[::-1]
        assert (cap[:km] + 1).sum() >= 30
        if km > 1:
            assert (cap[:km - 1] + 1).sum() < 30

    def test_greedy_fallback_feasible(self):
        inst = make_instance(25)
        p = StarMaskParams(k_max=12, m_min=2)
        clusters = greedy_fallback(inst, p)
        assert clusters is not None
        got = np.sort(np.concatenate(clusters))
        np.testing.assert_array_equal(got, np.arange(25))

    def test_reward_prefers_balanced_time(self):
        """Eq. 18: grouping similar t_comp beats mixing fast+slow."""
        n = 8
        inst = Instance(
            share=np.full(n, 1 / n), hw=np.zeros(n, int),
            t_comp=np.array([1, 1, 1, 1, 10, 10, 10, 10], float),
            e_train=np.ones(n), fanout=np.full(n, 5),
        )
        p = StarMaskParams()
        good = [np.array([0, 1, 2, 3]), np.array([4, 5, 6, 7])]
        bad = [np.array([0, 4, 1, 5]), np.array([2, 6, 3, 7])]
        rg, _ = reward(good, inst, p)
        rb, _ = reward(bad, inst, p)
        assert rg > rb

    def test_rl_training_improves_reward(self):
        from repro.core.starmask import train_policy, rollout
        insts = [make_instance(12, seed=s) for s in range(3)]
        p = StarMaskParams(k_max=6, m_min=1)
        params, hist = train_policy(insts, p, jax.random.PRNGKey(0),
                                    episodes=60, lr=5e-3)
        assert len(hist) >= 40
        early = np.mean(hist[:15])
        late = np.mean(hist[-15:])
        assert late >= early - 0.05   # no catastrophic degradation


# ---------------------------------------------------------------------------
# Skip-One
# ---------------------------------------------------------------------------

class TestSkipOne:
    def test_at_most_one_skip(self, rng):
        st = skipone.SkipOneState.init(8)
        p = skipone.SkipOneParams()
        for r in range(20):
            tt = rng.lognormal(1, 1, 8)
            ee = rng.lognormal(1, 0.5, 8)
            mask, st = skipone.select(tt, ee, np.zeros(8), st, p, r)
            assert (~mask).sum() <= 1

    def test_skips_dominant_straggler(self):
        st = skipone.SkipOneState.init(5)
        p = skipone.SkipOneParams()
        tt = np.array([1.0, 1.1, 9.0, 1.2, 1.0])
        ee = np.ones(5)
        mask, _ = skipone.select(tt, ee, np.zeros(5), st, p, 0)
        assert not mask[2]

    def test_cooldown_blocks_consecutive(self):
        st = skipone.SkipOneState.init(5)
        p = skipone.SkipOneParams(cooldown=2)
        tt = np.array([1.0, 1.0, 9.0, 1.0, 1.0])
        mask, st = skipone.select(tt, np.ones(5), np.zeros(5), st, p, 0)
        assert not mask[2] and st.kappa[2] == 2
        mask2, st = skipone.select(tt, np.ones(5), np.zeros(5), st, p, 1)
        assert mask2[2]          # on cooldown: must participate

    def test_periodic_full_round_resets(self):
        p = skipone.SkipOneParams(all_participate_every=3)
        st = skipone.SkipOneState(np.array([2, 0, 1]), np.array([1, 0, 3]),
                                  np.array([0.5, 0.0, 0.9]))
        mask, st2 = skipone.select(np.ones(3), np.ones(3), np.zeros(3),
                                   st, p, round_idx=2)
        assert mask.all()
        assert (st2.kappa == 0).all() and (st2.tau == 0).all()

    def test_barrier_weakly_reduced(self, rng):
        st = skipone.SkipOneState.init(6)
        p = skipone.SkipOneParams()
        tt = rng.lognormal(1, 1, 6)
        mask, _ = skipone.select(tt, np.ones(6), np.zeros(6), st, p, 0)
        assert tt[mask].max() <= tt.max()

    def test_jax_matches_numpy(self, rng):
        p = skipone.SkipOneParams()
        K, n = 3, 6
        tt = rng.lognormal(1, 1, (K, n))
        ee = rng.lognormal(1, 0.5, (K, n))
        hw = rng.random((K, n))
        kappa = np.zeros((K, n), int)
        tau = np.zeros((K, n), int)
        phi = np.zeros((K, n))
        mask_j, (k2, t2, p2) = skipone.select_jax(
            jnp.asarray(tt), jnp.asarray(ee), jnp.asarray(hw),
            jnp.asarray(kappa), jnp.asarray(tau), jnp.asarray(phi), p)
        for k in range(K):
            st = skipone.SkipOneState(kappa[k].copy(), tau[k].copy(),
                                      phi[k].copy())
            mask_np, _ = skipone.select(tt[k], ee[k], hw[k], st, p, 0)
            np.testing.assert_array_equal(np.asarray(mask_j[k]) > 0.5, mask_np)


# ---------------------------------------------------------------------------
# Cross-aggregation
# ---------------------------------------------------------------------------

class TestCrossAgg:
    def test_mixing_matrix_row_stochastic(self, rng):
        K = 9
        reach = rng.random((K, K)) < 0.4
        groups = crossagg.sample_groups(reach, 2, rng)
        M = crossagg.mixing_matrix(groups, rng.uniform(10, 100, K))
        np.testing.assert_allclose(M.sum(1), 1.0)
        assert (M >= 0).all()

    def test_group_size_bounded_eq35(self, rng):
        K, k_nbr = 12, 3
        reach = rng.random((K, K)) < 0.5
        groups = crossagg.sample_groups(reach, k_nbr, rng)
        for k, g in enumerate(groups):
            assert g[0] == k
            assert len(g) <= 1 + k_nbr
            nbrs = set(np.flatnonzero(reach[k] & (np.arange(K) != k)))
            assert set(g[1:]).issubset(nbrs)

    def test_empty_reach_is_identity(self, rng):
        K = 5
        groups = crossagg.sample_groups(np.zeros((K, K), bool), 2, rng)
        M = crossagg.mixing_matrix(groups, np.ones(K))
        np.testing.assert_allclose(M, np.eye(K))

    def test_consolidation_eq38(self, rng):
        K = 4
        models = {"w": jnp.asarray(rng.normal(size=(K, 7)))}
        n = np.array([10.0, 20.0, 30.0, 40.0])
        out = crossagg.consolidate(models, n)
        expect = (n[:, None] / n.sum() * np.asarray(models["w"])).sum(0)
        np.testing.assert_allclose(np.asarray(out["w"]), expect, rtol=1e-6)

    def test_mixing_preserves_consensus(self, rng):
        """If all clusters share the same model, mixing is a no-op."""
        K = 6
        w = rng.normal(size=(1, 5))
        models = {"w": jnp.asarray(np.repeat(w, K, 0))}
        reach = rng.random((K, K)) < 0.6
        groups = crossagg.sample_groups(reach, 2, rng)
        M = crossagg.mixing_matrix(groups, rng.uniform(1, 10, K))
        out = crossagg.apply_mixing(M, models)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(models["w"]), atol=1e-5)

    def test_gossip_converges_over_rounds(self, rng):
        """Repeated random-k mixing over a connected-on-average graph
        contracts disagreement (the paper's consensus claim)."""
        K = 8
        n = rng.uniform(10, 50, K)
        x = rng.normal(size=(K, 3))
        target = (n[:, None] / n.sum() * x).sum(0)
        disagreement = [np.abs(x - x.mean(0)).max()]
        for r in range(60):
            reach = rng.random((K, K)) < 0.35
            reach |= reach.T
            np.fill_diagonal(reach, False)
            groups = crossagg.sample_groups(reach, 2, rng)
            M = crossagg.mixing_matrix(groups, n)
            x = M @ x
            disagreement.append(np.abs(x - x.mean(0)).max())
        assert disagreement[-1] < 0.05 * disagreement[0]

    def test_jax_mixing_matrix(self, rng):
        K = 7
        reach = rng.random((K, K)) < 0.5
        M = crossagg.mixing_matrix_jax(jnp.asarray(reach),
                                       jnp.asarray(rng.uniform(1, 9, K)),
                                       2, jax.random.PRNGKey(3))
        M = np.asarray(M)
        np.testing.assert_allclose(M.sum(1), 1.0, atol=1e-5)
        # respects reachability + self
        for k in range(K):
            nz = set(np.flatnonzero(M[k] > 0))
            allowed = set(np.flatnonzero(reach[k])) | {k}
            assert nz.issubset(allowed)
            assert len(nz - {k}) <= 2


# ---------------------------------------------------------------------------
# Energy model
# ---------------------------------------------------------------------------

class TestEnergy:
    def test_eq2_4_runtime_scaling(self):
        # double data -> double FLOPs -> double time (Eq. 2-4)
        assert t_comp(200, 1e6, 1e9) == 2 * t_comp(100, 1e6, 1e9)
        # faster hardware -> proportionally less time
        assert t_comp(100, 1e6, 2e9) == t_comp(100, 1e6, 1e9) / 2

    def test_eq8_cpu_energy_quadratic_in_freq(self):
        p1 = HardwareProfile(CPU, 1e9, freq=1e9)
        p2 = HardwareProfile(CPU, 1e9, freq=2e9)
        e1 = e_train([100], 1e6, [p1], 1)[0]
        e2 = e_train([100], 1e6, [p2], 1)[0]
        assert np.isclose(e2 / e1, 4.0)

    def test_eq9_gpu_energy_power_times_time(self):
        p = HardwareProfile(GPU, 2e9, gpu_power=30.0)
        e = e_train([100], 1e6, [p], 5)[0]
        expect = 30.0 * t_train(100, 1e6, 2e9, 5)
        assert np.isclose(e, expect)

    def test_eq5_12_lisl(self):
        lp = LinkParams()
        d = 8 * 44.7e6
        t = t_lisl(d, lp.lisl_rate, 1e6, lp)
        assert np.isclose(t, d / lp.lisl_rate + 1e6 / lp.light_speed)
        assert np.isclose(e_lisl(d, lp.lisl_rate, 1e6, lp), lp.lisl_power * t)

    def test_eq13_gs_energy_dominates_lisl(self):
        """GS transfers cost more than LISL (40 W vs 10 W, half rate)."""
        lp = LinkParams()
        d = 8 * 44.7e6
        assert e_gs(d, lp.gs_rate, 1e6, lp) > 4 * e_lisl(d, lp.lisl_rate,
                                                         1e6, lp)

    def test_ledger_accounting(self):
        led = EnergyLedger()
        led.add_gs(2, 100.0, 10.0)
        led.add_intra(3, 30.0, 3.0)
        led.add_inter(1, 10.0, 1.0)
        led.add_train(500.0, 60.0)
        led.add_wait(120.0)
        assert led.gs_count == 2 and led.intra_lisl_count == 3
        assert led.transmission_energy_j == 140.0
        assert led.total_energy_j == 640.0
        row = led.row()
        assert np.isclose(row["waiting_h"], 120 / 3600)
