"""Model-stack correctness: decode-vs-prefill consistency, chunked ops vs
naive references, RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import api
from repro.models import layers as L

KEY = jax.random.PRNGKey(7)

# one representative per family (full sweep is in test_archs_smoke)
FAMILIES = ["stablelm-3b", "gemma3-1b", "deepseek-v2-236b", "xlstm-125m",
            "jamba-1.5-large-398b", "whisper-large-v3"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_prefill(arch):
    """Greedy next-token from prefill == greedy from step-by-step decode —
    the KV-cache/ring-buffer/SSM-state paths agree with the parallel path.

    capacity_factor is raised so no MoE tokens drop: capacity-based
    dropping is batch-global, so prefill (T tokens compete) and decode
    (1 token) legitimately differ when slots overflow."""
    cfg = get_config(arch).reduced(capacity_factor=64.0)
    params = api.init(cfg, KEY)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.rope_variant == "mrope":
        batch["position_ids"] = jnp.broadcast_to(
            jnp.arange(S), (3, B, S)).astype(jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model)
        ).astype(cfg.dtype)
    logits_pf = api.prefill(params, batch, cfg)

    # decode path: feed tokens one by one
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         api.cache_specs(cfg, B, S + 4))
    if cfg.is_encoder_decoder:
        # decode caches cross-attn k/v computed from the SAME frames:
        # prefill once through the decode path to fill xk/xv
        from repro.models.encdec import encode
        ctx = encode(params, batch["frames"], cfg, remat=False)
        Hkv, hd = cfg.num_kv_heads, cfg.hd
        xks, xvs = [], []
        nl = cfg.num_layers
        dl = params["dec_layers"]
        for l in range(nl):
            cp = jax.tree.map(lambda x: x[l], dl)["cross_attn"]
            Se = ctx.shape[1]
            xks.append((ctx @ cp["wk"]).reshape(B, Se, Hkv, hd))
            xvs.append((ctx @ cp["wv"]).reshape(B, Se, Hkv, hd))
        cache = dict(cache)
        cache["xk"] = jnp.stack(xks).astype(cache["xk"].dtype)
        cache["xv"] = jnp.stack(xvs).astype(cache["xv"].dtype)

    logits_dec = None
    for t in range(S):
        db = {"token": toks[:, t:t + 1],
              "pos": jnp.full((B,), t, jnp.int32), "cache": cache}
        if cfg.rope_variant == "mrope":
            db["position_ids"] = jnp.full((3, B, 1), t, jnp.int32)
        logits_dec, cache = api.decode_step(params, db, cfg)

    lp = np.asarray(logits_pf, np.float32)
    ld = np.asarray(logits_dec, np.float32)
    # bf16 stacks: compare top-1 agreement and correlation
    assert (lp.argmax(-1) == ld.argmax(-1)).all(), f"{arch}: top-1 mismatch"
    corr = np.corrcoef(lp.ravel(), ld.ravel())[0, 1]
    assert corr > 0.99, f"{arch}: corr {corr}"


def test_chunked_ce_matches_naive():
    B, S, D, V = 2, 64, 16, 50
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    w = jax.random.normal(ks[1], (D, V), jnp.float32)
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    got = L.chunked_ce_loss(h, w, labels, chunk=16)
    logits = h @ w
    logp = jax.nn.log_softmax(logits)
    ref = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


def test_chunked_attention_matches_naive():
    from repro.kernels.flash_attention.ref import flash_attention_ref
    B, S, H, Hkv, d = 2, 96, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, d), jnp.float32)
    got = L.chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, ref, atol=2e-5)


def test_causal_skip_schedule_matches_full():
    """The triangular (beyond-paper) schedule equals the dense schedule."""
    B, S, H, d = 1, 128, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, d), jnp.float32)
    full = L.chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32)
    skip = L.chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32,
                               causal_skip=True)
    np.testing.assert_allclose(skip, full, atol=2e-5)


def test_sliding_window_masks_past():
    """SWA: positions beyond the window contribute nothing."""
    B, S, H, d, W = 1, 64, 2, 16, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, d), jnp.float32)
    out1 = L.chunked_attention(q, k, v, causal=True, window=W,
                               chunk_q=16, chunk_k=16)
    # perturb k/v outside the window of the last query: no effect
    k2 = k.at[:, : S - W - 1].add(100.0)
    v2 = v.at[:, : S - W - 1].add(100.0)
    out2 = L.chunked_attention(q, k2, v2, causal=True, window=W,
                               chunk_q=16, chunk_k=16)
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], atol=1e-4)


def test_rope_relative_property():
    """RoPE: q.k depends only on relative position."""
    d = 32
    q = jax.random.normal(KEY, (1, 1, 1, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(9), (1, 1, 1, d), jnp.float32)

    def score(pq, pk):
        cq, sq = L.rope_angles(jnp.array([pq]), d, 10_000.0)
        ck, sk = L.rope_angles(jnp.array([pk]), d, 10_000.0)
        qr = L.apply_rope(q, cq, sq)
        kr = L.apply_rope(k, ck, sk)
        return float((qr * kr).sum())

    assert abs(score(3, 7) - score(13, 17)) < 1e-4
    assert abs(score(3, 7) - score(3, 8)) > 1e-6


def test_moe_routes_and_balances():
    from repro.models.layers import moe_fwd, moe_params, ParamFactory
    # high capacity factor -> no drops -> batch rows are independent
    cfg = get_config("qwen2-moe-a2.7b").reduced(capacity_factor=64.0)
    pf = ParamFactory(KEY, jnp.float32)
    p = moe_params(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = moe_fwd(p, x, cfg)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all() and jnp.isfinite(aux)
    assert float(aux) >= 0.0
    # routing responds to input: different tokens -> different outputs
    x2 = x.at[0].add(1.0)
    out2, _ = moe_fwd(p, x2, cfg)
    assert not np.allclose(np.asarray(out[0]), np.asarray(out2[0]))
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(out2[1]),
                               atol=1e-6)


def test_ring_cache_decode_equals_window_attention():
    """SWA decode via ring buffer == full attention with window mask."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = api.init(cfg, KEY)
    B, S = 1, 20   # window in reduced config = 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              cfg.vocab_size)
    logits_pf = api.prefill(params, {"tokens": toks}, cfg)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         api.cache_specs(cfg, B, S))
    logits = None
    for t in range(S):
        db = {"token": toks[:, t:t + 1], "pos": jnp.full((B,), t, jnp.int32),
              "cache": cache}
        logits, cache = api.decode_step(params, db, cfg)
    assert (np.asarray(logits_pf).argmax(-1) ==
            np.asarray(logits).argmax(-1)).all()


def test_moe_grouped_matches_flat():
    """Group-local dispatch (the §Perf EP layout) == flat dispatch when no
    tokens drop (capacity_factor high, Tl >= 64 so the grouped path runs)."""
    import dataclasses
    from repro.models.layers import moe_fwd, moe_params, ParamFactory
    cfg = get_config("qwen2-moe-a2.7b").reduced(capacity_factor=64.0)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    pf = ParamFactory(KEY, jnp.float32)
    p = moe_params(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64, cfg.d_model),
                          jnp.float32)
    out_flat, _ = moe_fwd(p, x, cfg)
    cfg_g = dataclasses.replace(cfg, moe_groups=4)
    out_grp, _ = moe_fwd(p, x, cfg_g)
    np.testing.assert_allclose(np.asarray(out_flat), np.asarray(out_grp),
                               atol=2e-5)
