"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs one
forward/train step and one decode step on CPU, asserting output shapes and
no NaNs. Full configs are only exercised via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, input_specs
from repro.models import api


def make_batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.frontend == "patches":
        batch["patch_embeds"] = jnp.zeros((B, cfg.num_patches, cfg.d_model),
                                          cfg.dtype)
    if cfg.rope_variant == "mrope":
        batch["position_ids"] = jnp.broadcast_to(
            jnp.arange(S), (3, B, S)).astype(jnp.int32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                    cfg.dtype)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, key):
    cfg = get_config(arch).reduced()
    params = api.init(cfg, key)
    batch = make_batch(cfg)
    loss = api.train_loss(params, batch, cfg)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    # gradients flow and are finite
    g = jax.grad(lambda p: api.train_loss(p, batch, cfg))(params)
    leaves = jax.tree.leaves(g)
    assert all(jnp.isfinite(l).all() for l in leaves), f"{arch}: NaN grads"
    assert any(jnp.abs(l.astype(jnp.float32)).max() > 0 for l in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch, key):
    cfg = get_config(arch).reduced()
    params = api.init(cfg, key)
    B, S = 2, 16
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         api.cache_specs(cfg, B, S))
    batch = {"token": jnp.ones((B, 1), jnp.int32),
             "pos": jnp.full((B,), 3, jnp.int32), "cache": cache}
    if cfg.rope_variant == "mrope":
        batch["position_ids"] = jnp.full((3, B, 1), 3, jnp.int32)
    logits, new_cache = api.decode_step(params, batch, cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite decode logits"
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_init(arch, key):
    """Analytic count == actual initialized parameter count."""
    cfg = get_config(arch).reduced()
    params = api.init(cfg, key)
    actual = sum(l.size for l in jax.tree.leaves(params))
    assert api.count_params(cfg) == actual


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_complete(arch):
    """input_specs covers every dry-run shape without allocation."""
    cfg = get_config(arch)
    for shape in SHAPES.values():
        specs = input_specs(cfg, shape)
        assert all(isinstance(s, jax.ShapeDtypeStruct)
                   for s in jax.tree.leaves(specs))
        if shape.kind in ("train", "prefill"):
            assert specs["tokens"].shape == (shape.global_batch,
                                             shape.seq_len)


def test_full_param_counts_match_literature():
    """Full configs land on the published sizes."""
    expect = {
        "qwen2-vl-7b": (7.6e9, 0.1), "stablelm-3b": (2.8e9, 0.15),
        "granite-34b": (34e9, 0.05), "gemma3-1b": (1.0e9, 0.1),
        "h2o-danube-1.8b": (1.8e9, 0.05), "whisper-large-v3": (1.55e9, 0.05),
        "deepseek-v2-236b": (236e9, 0.02), "jamba-1.5-large-398b": (398e9, 0.02),
    }
    for arch, (n, tol) in expect.items():
        got = api.count_params(get_config(arch))
        assert abs(got - n) / n < tol, f"{arch}: {got:.3e} vs {n:.3e}"
    # active params for the MoE archs
    assert abs(api.count_params(get_config("deepseek-v2-236b"),
                                active_only=True) - 21e9) / 21e9 < 0.1
    assert abs(api.count_params(get_config("qwen2-moe-a2.7b"),
                                active_only=True) - 2.7e9) / 2.7e9 < 0.1
