"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cross_agg import (cross_agg_flat, cross_agg_flat_ref,
                                     cross_agg_tree, cross_agg_tree_ref)
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.quant import (compress_tree, decompress_tree,
                                 int8_dequantize, int8_dequantize_ref,
                                 int8_quantize, int8_quantize_ref)

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# cross_agg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K,N", [(2, 100), (9, 5000), (16, 4096), (5, 7777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cross_agg_flat(K, N, dtype):
    k1, k2 = jax.random.split(KEY)
    M = jax.nn.softmax(jax.random.normal(k1, (K, K)), -1)
    W = jax.random.normal(k2, (K, N)).astype(dtype)
    out = cross_agg_flat(M, W, tile_n=512)
    ref = cross_agg_flat_ref(M, W)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_cross_agg_tree_matches_ref():
    k1, k2 = jax.random.split(KEY)
    K = 4
    tree = {"a": jax.random.normal(k1, (K, 17, 9)),
            "b": {"c": jax.random.normal(k2, (K, 33))}}
    M = jax.nn.softmax(jax.random.normal(KEY, (K, K)), -1)
    out = cross_agg_tree(M, tree)
    ref = cross_agg_tree_ref(M, tree)
    for o, r in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(o, r, atol=1e-5)


def test_cross_agg_identity_mixing():
    """M = I must be a no-op (paper: empty reach set)."""
    W = jax.random.normal(KEY, (6, 1000))
    out = cross_agg_flat(jnp.eye(6), W)
    np.testing.assert_allclose(out, W, atol=1e-6)


# ---------------------------------------------------------------------------
# cross_agg as a mixing backend (core/crossagg.apply_mixing routing)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 3, 5, 9])
def test_apply_mixing_pallas_matches_einsum_on_sampled_groups(K):
    """The engine's real matrices: sample_groups -> mixing_matrix applied
    through the Pallas kernel vs the einsum reference, at non-square
    cluster counts and non-tile-aligned leaf widths."""
    from repro.core import crossagg
    rng = np.random.default_rng(K)
    reach = rng.random((K, K)) < 0.6
    groups = crossagg.sample_groups(reach, 2, rng)
    M = crossagg.mixing_matrix(groups,
                               rng.integers(1, 50, K).astype(np.float64))
    tree = {"a": jnp.asarray(rng.standard_normal((K, 13, 7)), jnp.float32),
            "b": {"c": jnp.asarray(rng.standard_normal((K, 301)),
                                   jnp.float32)}}
    out = crossagg.apply_mixing(M, tree, backend="pallas")
    ref = crossagg.apply_mixing(M, tree)
    for o, r in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        assert o.shape == r.shape and o.dtype == r.dtype
        np.testing.assert_allclose(o, r, atol=1e-5, rtol=1e-5)


def test_metropolis_consensus_pallas_matches_reference():
    """Gossip finalize path: repeated Metropolis consensus applications
    through the kernel track the einsum reference."""
    from repro.core import crossagg
    rng = np.random.default_rng(0)
    K = 6
    adj = rng.random((K, K)) < 0.4
    adj |= adj.T
    for i in range(K):                       # ring keeps the graph connected
        adj[i, (i + 1) % K] = adj[(i + 1) % K, i] = True
    M = crossagg.metropolis_matrix(adj)
    x_p = x_e = {"w": jnp.asarray(rng.standard_normal((K, 97)), jnp.float32)}
    for _ in range(3):
        x_p = crossagg.apply_mixing(M, x_p, backend="pallas")
        x_e = crossagg.apply_mixing(M, x_e)
    np.testing.assert_allclose(x_p["w"], x_e["w"], atol=1e-5, rtol=1e-5)
    sigma2 = crossagg.consensus_contraction(M, np.ones(K))
    assert 0.0 <= sigma2 < 1.0               # connected -> contraction


def test_apply_mixing_pallas_zero_clusters():
    """A zero-participant round builds a (0, 0) matrix over (0, ...)
    leaves; both backends must pass it through without crashing."""
    from repro.core import crossagg
    tree = {"w": jnp.zeros((0, 12)), "b": jnp.zeros((0, 3, 5))}
    M = np.zeros((0, 0))
    for backend in ("einsum", "pallas"):
        out = crossagg.apply_mixing(M, tree, backend=backend)
        for o, r in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            assert o.shape == r.shape


def test_apply_mixing_unknown_backend_raises():
    from repro.core import crossagg
    with pytest.raises(ValueError):
        crossagg.apply_mixing(np.eye(2), {"w": jnp.zeros((2, 4))},
                              backend="cuda")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hkv,S,d", [
    (1, 4, 4, 128, 64),      # MHA
    (2, 4, 2, 256, 64),      # GQA
    (1, 8, 1, 128, 128),     # MQA
    (2, 2, 2, 384, 32),      # non-pow2 seq blocks
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Hq, Hkv, S, d, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 256, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 256, 64)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_flash_attention_matches_model_path():
    """Kernel agrees with the model stack's chunked_attention."""
    from repro.models.layers import chunked_attention
    ks = jax.random.split(KEY, 3)
    B, S, H, Hkv, d = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, d), jnp.float32)
    ref = chunked_attention(q, k, v, causal=True)
    out = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True,
                          block_q=128, block_k=128).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, atol=2e-5)


# ---------------------------------------------------------------------------
# quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(100,), (300, 77), (8, 1024), (3, 5, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matches_ref(shape, dtype):
    x = (jax.random.normal(KEY, shape) * 3).astype(dtype)
    q, s = int8_quantize(x)
    qr, sr = int8_quantize_ref(x)
    assert int(jnp.abs(q.astype(jnp.int32) - qr.astype(jnp.int32)).max()) <= 1
    np.testing.assert_allclose(s, sr, rtol=1e-6)


def test_quant_roundtrip_error_bound():
    x = jax.random.normal(KEY, (200, 300)) * 5
    q, s = int8_quantize(x)
    xd = int8_dequantize(q, s, n=x.size, shape=x.shape, dtype=jnp.float32)
    # symmetric int8: error <= scale/2 = absmax/254 per chunk
    err = jnp.abs(xd - x).max()
    assert float(err) <= float(jnp.abs(x).max()) / 127.0


def test_quant_tree_roundtrip():
    tree = {"w": jax.random.normal(KEY, (50, 60)),
            "b": jax.random.normal(KEY, (3000,)) * 0.01}
    ct = compress_tree(tree)
    out = decompress_tree(ct)
    for o, r in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        rel = float(jnp.abs(o - r).max() / (jnp.abs(r).max() + 1e-12))
        assert rel < 0.02
