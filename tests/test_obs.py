"""repro.obs acceptance tests (DESIGN.md §10).

The load-bearing guarantees:

1. observers DISABLED -> the engine's ledgers are bit-for-bit identical
   to an observer-free run (the golden-parity suite keeps covering the
   pre-obs behavior; here we pin on/off equality directly);
2. observers ENABLED -> the TracingObserver's mirror ledger reconciles
   BIT-EXACT with the engine's EnergyLedger for a full CroSatFL session
   and a baseline (every joule/second traced exactly once, in order);
3. the report reproduces the paper columns (GS contact count, per-phase
   energies) from the trace alone — no ledger access;
4. every emitted event validates against the versioned JSONL schema.

Plus unit coverage of SpanTracer / Metrics / schema validation.
"""
import json
import os

import pytest

from golden_capture import baseline_config, build_setup, session_config
from repro.core.session import Session
from repro.fl.baselines import BASELINES
from repro.obs import (Metrics, SpanTracer, TRACE_SCHEMA_VERSION,
                       TracingObserver, load_events, validate_event)
from repro.obs.report import breakdown_table, summarize


# ---------------------------------------------------------------------------
# traced runs (one per module; ledgers are host-side numpy -> reproducible)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_crosatfl(tmp_path_factory):
    jsonl = str(tmp_path_factory.mktemp("obs") / "crosatfl.jsonl")
    obs = TracingObserver(jsonl)
    env, model = build_setup()
    _, ledger, _ = Session(session_config(model), env, model,
                           observer=obs).run()
    return obs, ledger, jsonl


@pytest.fixture(scope="module")
def traced_baseline():
    obs = TracingObserver()
    env, model = build_setup()
    _, ledger, _ = BASELINES["FedSyn"](baseline_config(model), env, model,
                                       observer=obs).run()
    return obs, ledger


# ---------------------------------------------------------------------------
# 1. observer off == no observer, bit for bit
# ---------------------------------------------------------------------------

def test_disabled_observer_preserves_ledger_bits():
    env, model = build_setup()
    _, plain, _ = Session(session_config(model), env, model).run()
    env, model = build_setup()
    _, observed, _ = Session(session_config(model), env, model,
                             observer=TracingObserver()).run()
    assert plain.snapshot() == observed.snapshot()


# ---------------------------------------------------------------------------
# 2. mirror-ledger reconciliation, bit exact
# ---------------------------------------------------------------------------

def test_crosatfl_reconciles_bit_exact(traced_crosatfl):
    obs, ledger, _ = traced_crosatfl
    rec = obs.reconcile(ledger)
    bad = {k: v for k, v in rec["fields"].items() if not v["equal"]}
    assert rec["exact"], f"mirror != ledger: {bad}"


def test_baseline_reconciles_bit_exact(traced_baseline):
    obs, ledger = traced_baseline
    assert obs.reconcile(ledger)["exact"]


def test_metric_sums_reconcile_bit_exact(traced_crosatfl):
    """Per-(round x cluster) and per-link decompositions sum back to the
    ledger fields with the SAME floats (in-order accumulation)."""
    obs, ledger, _ = traced_crosatfl
    m = obs.metrics
    assert m.total("train_joules") == ledger.train_energy_j
    assert m.get("gs_joules_inorder") == ledger.gs_energy_j
    assert m.get("lisl_joules_inorder") == ledger.lisl_energy_j
    # the decomposition is real: >1 series, every round/cluster labelled
    series = m.series("train_joules")
    assert len(series) > 1
    assert all({"round", "cluster"} <= set(lab) for lab, _ in series)


# ---------------------------------------------------------------------------
# 3. report columns from the trace alone
# ---------------------------------------------------------------------------

def test_report_reproduces_ledger_columns(traced_crosatfl):
    obs, ledger, jsonl = traced_crosatfl
    s = summarize(load_events(jsonl))            # from the FILE, not memory
    assert s["algo"] == "CroSatFL"
    assert s["gs_comm"] == ledger.gs_count
    assert s["train_j"] == ledger.train_energy_j
    assert s["gs_j"] == ledger.gs_energy_j
    assert s["lisl_j"] == ledger.lisl_energy_j
    assert s["wait_s"] == ledger.waiting_time_s
    assert s["rounds"] == 3 and len(s["round_latencies"]) == 3
    table = breakdown_table([s])
    assert "CroSatFL" in table and "GS msgs" in table


def test_report_baseline_columns(traced_baseline):
    obs, ledger = traced_baseline
    s = summarize(obs.tracer.events)
    assert s["gs_comm"] == ledger.gs_count
    assert s["train_j"] == ledger.train_energy_j
    assert s["gs_j"] == ledger.gs_energy_j


# ---------------------------------------------------------------------------
# 4. schema
# ---------------------------------------------------------------------------

def test_all_emitted_events_validate(traced_crosatfl, traced_baseline):
    for obs in (traced_crosatfl[0], traced_baseline[0]):
        errs = [e for ev in obs.tracer.events for e in validate_event(ev)]
        assert errs == []
        assert all(ev["v"] == TRACE_SCHEMA_VERSION
                   for ev in obs.tracer.events)


def test_validate_rejects_malformed():
    assert validate_event("nope")
    assert validate_event({"v": 99, "kind": "comm"})
    assert any("unknown kind" in e for e in
               validate_event({"v": 1, "kind": "bogus", "t_host": 0.0}))
    ok = {"v": 1, "kind": "comm", "t_host": 0.0, "link": "gs", "n": 2,
          "bits": 1.0, "energy_j": 1.0, "time_s": 0.5, "phase": "round",
          "round": 0, "cluster": None}
    assert validate_event(ok) == []
    assert any("comm.link" in e for e in
               validate_event({**ok, "link": "laser"}))
    assert any("missing field" in e for e in
               validate_event({k: v for k, v in ok.items() if k != "n"}))


def test_sim_event_records_validate_and_count():
    """Kernel events (repro.sim) stream through the same schema'd trace:
    validated, counted per etype, and the driver-stamped round in the
    payload wins over the observer's own round cursor."""
    obs = TracingObserver()
    obs.round_start(0, 0.0)
    obs.sim_event("train_done", 12.5, cluster=1, seq=3, barrier=2.5)
    obs.sim_event("merge_commit", 99.0, round=7, staleness=4.0)
    evs = [e for e in obs.tracer.events if e["kind"] == "sim_event"]
    assert [e for ev in evs for e in validate_event(ev)] == []
    assert evs[0]["round"] == 0 and evs[0]["barrier"] == 2.5
    assert evs[1]["round"] == 7                   # payload round wins
    assert obs.metrics.get("sim_events", etype="train_done") == 1.0


def test_latency_histogram_single_bin():
    """Regression: a degenerate (all-identical) latency distribution —
    every single-round trace — used to render 8 zero-width buckets with
    the whole mass in the first; now it is one explicit bin."""
    from repro.obs.report import latency_histogram
    one = latency_histogram([120.0])
    assert len(one) == 1 and "all 1 round identical" in one[0]
    two = latency_histogram([5.0, 5.0])
    assert len(two) == 1 and "all 2 rounds identical" in two[0]
    assert latency_histogram([]) == ["  (no rounds)"]
    spread = latency_histogram([1.0, 2.0, 9.0], bins=8)
    assert len(spread) == 8                       # normal path unchanged


# ---------------------------------------------------------------------------
# SpanTracer units
# ---------------------------------------------------------------------------

def test_tracer_jsonl_stream_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tr = SpanTracer(path)
    tr.emit("round_start", round=0, sim_t=0.0)
    tr.emit("round_end", round=0, sim_t=5.0, sim_dur=5.0, host_dur=0.01)
    tr.close()
    assert load_events(path) == tr.events
    assert all(validate_event(ev) == [] for ev in tr.events)


def test_tracer_spans_measure_host_time():
    tr = SpanTracer()
    tr.begin_span("train")
    ev = tr.end_span("train", sim_t0=10.0, sim_dur=3.0)
    assert ev["kind"] == "phase" and ev["name"] == "train"
    assert ev["host_dur"] >= 0.0 and ev["sim_dur"] == 3.0


def test_chrome_trace_dual_timeline(tmp_path, traced_crosatfl):
    obs, _, _ = traced_crosatfl
    path = str(tmp_path / "trace.json")
    obs.tracer.to_chrome_trace(path)
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    pids = {e["pid"] for e in evs}
    assert pids == {1, 2}                      # sim + host timelines
    tracks = {e["args"]["name"] for e in evs
              if e.get("name") == "thread_name"}
    assert "GS" in tracks and "rounds" in tracks
    assert any(t.startswith("cluster") for t in tracks)
    assert any(e["ph"] == "X" and e["pid"] == 2 for e in evs)


# ---------------------------------------------------------------------------
# Metrics units
# ---------------------------------------------------------------------------

def test_metrics_counters_and_series():
    m = Metrics()
    m.count("e", 1.5, round=0, cluster=0)
    m.count("e", 2.5, round=0, cluster=1)
    m.count("e", 4.0, round=1, cluster=0)
    m.count("other", 99.0)
    assert m.get("e", round=0, cluster=1) == 2.5
    assert m.total("e") == 8.0
    assert m.total("e", round=0) == 4.0
    assert [v for _, v in m.series("e", cluster=0)] == [1.5, 4.0]


def test_metrics_histogram_and_gauge():
    m = Metrics()
    for v in (1.0, 2.0, 2.5, 9.0):
        m.observe("lat", v)
    bins = m.histogram("lat", bins=4)
    assert len(bins) == 4 and sum(c for _, _, c in bins) == 4
    m.gauge("clusters", 4)
    d = m.to_dict()
    assert d["gauges"]["clusters"][0]["value"] == 4
    assert "lat" in d["histograms"]


def test_metrics_json_export(tmp_path):
    m = Metrics()
    m.count("x", 1.0, phase="round")
    p = os.path.join(tmp_path, "m.json")
    m.to_json(p)
    with open(p) as f:
        d = json.load(f)
    assert d["counters"]["x"][0] == {"labels": {"phase": "round"},
                                     "value": 1.0}
