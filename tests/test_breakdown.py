"""repro.launch.breakdown on a small synthetic-HLO golden.

The module is a 4-trip ``while`` loop (the shape every lax.scan lowers
to) whose body does one all-reduce, so the expected attribution is
hand-computable:

  * collective: all-reduce of f32[128] = 512 B result, ring multiplier
    2x, executed 4 times -> 4096 B under op_name tail
    ``body/grad/all_reduce``.
  * memory: body ``add`` (4 B result + 4 B non-constant operand) x 4
    trips = 32 B, entry ``add`` (512 result + 512 + 512 operands)
    = 1536 B, plus the all-reduce's own 1024 B x 4 trips.
"""
import os

from repro.launch.breakdown import analyze, breakdown, _opname

GOLDEN_HLO = """\
%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[128]) %p), index=0
  %x = f32[128] get-tuple-element((s32[], f32[128]) %p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(s32[] %i, s32[] %one)
  %ar = f32[128] all-reduce(f32[128] %x), replica_groups={}, op_name="jit(step)/while/body/grad/all_reduce"
  ROOT %t = (s32[], f32[128]) tuple(s32[] %ni, f32[128] %ar)
}

%cond (p.1: (s32[], f32[128])) -> pred[] {
  %p.1 = (s32[], f32[128]) parameter(0)
  %i.1 = s32[] get-tuple-element((s32[], f32[128]) %p.1), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(s32[] %i.1, s32[] %n), direction=LT
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128]) tuple(s32[] %zero, f32[128] %a)
  %w = (s32[], f32[128]) while((s32[], f32[128]) %init), condition=%cond, body=%body
  %res = f32[128] get-tuple-element((s32[], f32[128]) %w), index=1
  ROOT %out = f32[128] add(f32[128] %res, f32[128] %a)
}
"""


def test_collective_attribution_golden():
    res = analyze(GOLDEN_HLO)
    # one all-reduce of 512 B, 2x ring multiplier, 4 loop trips
    assert res["collective"] == {
        ("all-reduce", "body/grad/all_reduce"): 4096.0}
    assert res["collective_total"] == 4096.0
    assert res["t_coll_s"] == 4096.0 / 50e9


def test_memory_attribution_golden():
    res = analyze(GOLDEN_HLO)
    mem = res["memory"]
    # body add: (4 B result + 4 B gte operand; constant excluded) x 4
    # entry add: 512 B result + 512 + 512 B operands, once
    assert mem[("add", "(none)")] == 4 * 8 + 1536
    # the all-reduce's HBM traffic: (512 result + 512 operand) x 4
    assert mem[("all-reduce", "body/grad/all_reduce")] == 4096.0
    assert res["memory_total"] == sum(mem.values())
    # tuple/get-tuple-element/parameter/constant/while contribute nothing
    assert all(op in ("add", "all-reduce") for op, _ in mem)


def test_no_entry_is_empty():
    res = analyze("")
    assert res["collective_total"] == 0.0
    assert res["memory_total"] == 0.0
    assert res["collective"] == {} and res["memory"] == {}


def test_opname_tail():
    assert _opname('x op_name="jit(step)/while/body/grad/all_reduce" y') \
        == "body/grad/all_reduce"
    assert _opname("no metadata here") == "(none)"


def test_breakdown_renders_from_file(tmp_path, capsys):
    p = os.path.join(tmp_path, "cell.hlo")
    with open(p, "w") as f:
        f.write(GOLDEN_HLO)
    res = breakdown(p, top=5)
    assert res == analyze(GOLDEN_HLO)
    out = capsys.readouterr().out
    assert "collective bytes" in out and "all-reduce" in out
