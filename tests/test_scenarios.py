"""Scenario zoo on the round engine (DESIGN.md §8): pacing policies
(semi-sync deadline, async staleness-weighted), gossip-only sessions,
per-cluster codec maps — plus the zero-participant guard. Policy-level
tests use a toy vector model; integration tests run one real round per
scenario preset on the shared tiny setup."""
import dataclasses
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import EnergyLedger, LinkParams, e_lisl
from repro.fl.engine import (SCENARIO_NAMES, AsyncPacing, BlockMinifloatCodec,
                             CodecMap, EngineConfig, GSStarMixing,
                             RelayedGSStarMixing, RoundEngine, RoundSelection,
                             SemiSyncPacing, SingleCluster, TopMEnergyUtility,
                             Transport, make_crosatfl, make_scenario)
from repro.fl.engine.base import EngineContext
from repro.fl.engine.mixing import _GSCentricMixing

from golden_capture import build_setup, session_config


@pytest.fixture(scope="module")
def setup():
    return build_setup()


def scenario_engine(name, env, model, rounds=1, **kw):
    scfg = session_config(model)
    cfg = dataclasses.replace(scfg.engine_config(), rounds=rounds)
    return make_scenario(name, cfg, env, model, k_nbr=scfg.k_nbr,
                         starmask=scfg.starmask, **kw)


def crosatfl_engine(env, model, rounds=1, **kw):
    scfg = session_config(model)
    cfg = dataclasses.replace(scfg.engine_config(), rounds=rounds)
    return make_crosatfl(cfg, env, model, k_nbr=scfg.k_nbr,
                         starmask=scfg.starmask, **kw)


# ---------------------------------------------------------------------------
# Pacing policies (unit level, toy vector model)
# ---------------------------------------------------------------------------

class _VecModel:
    """Minimal model duck-type: params are plain (d,) vectors."""

    def stack(self, params_list):
        return jnp.stack([jnp.asarray(p, jnp.float32) for p in params_list])

    def unstack(self, stacked, k):
        return [stacked[i] for i in range(k)]


def _ctx(et_full):
    led = EnergyLedger()
    return EngineContext(
        cfg=EngineConfig(), env=None, model=None,
        transport=Transport(led, LinkParams(), 1e6),
        rng=np.random.default_rng(0), tt_full=np.zeros(0),
        et_full=np.asarray(et_full, float), hw_penalty=np.zeros(0))


def _sel(tt, ids=None):
    tt = np.asarray(tt, float)
    ids = np.asarray(ids if ids is not None else np.arange(len(tt)))
    return RoundSelection(ids, np.ones(len(tt), bool), tt)


class TestSemiSyncPacing:
    def test_deadline_defers_straggler_then_folds_next_round(self):
        pac = SemiSyncPacing(quantile=0.5, beta=0.5)
        model = _VecModel()
        ctx = _ctx([1.0, 1.0])
        state = SimpleNamespace(
            cluster_models=model.stack([np.zeros(2), np.zeros(2)]))

        # round 0: cluster 0 finishes at 1s, cluster 1 at 10s; the 0.5
        # quantile deadline (5.5s) defers cluster 1's update
        pac.begin_round(ctx, 0)
        sels = [_sel([1.0], ids=[0]), _sel([10.0], ids=[1])]
        b = [pac.account_cluster(ctx, sels[0], 0),
             pac.account_cluster(ctx, sels[1], 1)]
        fresh = [jnp.ones(2), 2.0 * jnp.ones(2)]
        merged = pac.merge(ctx, model, state, fresh, sels, 0)
        np.testing.assert_allclose(np.asarray(merged[0]), 1.0)   # on time
        np.testing.assert_allclose(np.asarray(merged[1]), 0.0)   # deferred
        assert pac.advance(b) == 5.5                             # deadline
        assert 1 in pac._pending

        # round 1: both on time; the stash folds in with weight beta
        state.cluster_models = merged
        pac.begin_round(ctx, 1)
        sels = [_sel([1.0], ids=[0]), _sel([1.0], ids=[1])]
        for kc in range(2):
            pac.account_cluster(ctx, sels[kc], kc)
        fresh = [3.0 * jnp.ones(2), 4.0 * jnp.ones(2)]
        merged = pac.merge(ctx, model, state, fresh, sels, 1)
        np.testing.assert_allclose(np.asarray(merged[0]), 3.0)
        # (1-beta)*fresh + beta*late = 0.5*4 + 0.5*2
        np.testing.assert_allclose(np.asarray(merged[1]), 3.0)
        assert not pac._pending

    def test_nobody_waits_past_the_deadline(self):
        """On-time members idle to the deadline; a straggler's overshoot
        is training, not waiting."""
        pac = SemiSyncPacing(deadline_s=4.0)
        ctx = _ctx([1.0, 1.0])
        pac.begin_round(ctx, 0)
        sels = [_sel([1.0], ids=[0]), _sel([10.0], ids=[1])]
        for kc in range(2):
            pac.account_cluster(ctx, sels[kc], kc)
        pac.merge(ctx, _VecModel(),
                  SimpleNamespace(cluster_models=_VecModel().stack(
                      [np.zeros(1), np.zeros(1)])),
                  [jnp.zeros(1), jnp.zeros(1)], sels, 0)
        # cluster 0's member idles 4-1=3s; the straggler idles nothing
        assert ctx.ledger.waiting_time_s == 3.0
        assert pac.advance([1.0, 10.0]) == 4.0

    def test_generous_deadline_books_no_phantom_waiting(self):
        """Regression: a fixed deadline_s far beyond every barrier must
        degrade to sync (round closes when all clusters are done) — idle
        time is never booked past the wall-clock end of the round."""
        pac = SemiSyncPacing(deadline_s=3600.0)
        ctx = _ctx([1.0, 1.0])
        pac.begin_round(ctx, 0)
        sels = [_sel([1.0], ids=[0]), _sel([2.0], ids=[1])]
        for kc in range(2):
            pac.account_cluster(ctx, sels[kc], kc)
        model = _VecModel()
        merged = pac.merge(
            ctx, model,
            SimpleNamespace(cluster_models=model.stack([np.zeros(1),
                                                        np.zeros(1)])),
            [5.0 * jnp.ones(1), 6.0 * jnp.ones(1)], sels, 0)
        assert pac.advance([1.0, 2.0]) == 2.0    # not 3600
        assert ctx.ledger.waiting_time_s == 1.0  # member 0 idles 2-1 only
        assert not pac._pending                  # everyone is on time
        np.testing.assert_allclose(np.asarray(merged), [[5.0], [6.0]])

    def test_param_validation(self):
        with pytest.raises(ValueError):
            SemiSyncPacing(quantile=0.0)
        with pytest.raises(ValueError):
            SemiSyncPacing(beta=1.5)


class TestAsyncPacing:
    def test_staleness_weights_follow_arrival_rank(self):
        pac = AsyncPacing(alpha0=0.6, decay=1.0)
        a = pac.staleness_weights(np.array([3.0, 1.0, 2.0]))
        np.testing.assert_allclose(a, [0.6 / 3, 0.6, 0.6 / 2])

    def test_merge_is_staleness_weighted_convex_combination(self):
        pac = AsyncPacing(alpha0=0.5, decay=1.0)
        model = _VecModel()
        ctx = _ctx([1.0, 1.0])
        state = SimpleNamespace(
            cluster_models=model.stack([np.zeros(3), np.zeros(3)]))
        pac.begin_round(ctx, 0)
        sels = [_sel([2.0], ids=[0]), _sel([1.0], ids=[1])]
        b = [pac.account_cluster(ctx, sels[kc], kc) for kc in range(2)]
        merged = pac.merge(ctx, model, state,
                           [jnp.ones(3), jnp.ones(3)], sels, 0)
        # cluster 1 arrives first (rank 0, alpha=0.5); cluster 0 second
        # (rank 1, alpha=0.25); old models are zero
        np.testing.assert_allclose(np.asarray(merged[0]), 0.25)
        np.testing.assert_allclose(np.asarray(merged[1]), 0.5)
        # async wall clock advances by the MEAN cluster cycle, not the max
        assert pac.advance(b) == pytest.approx(1.5)


class TestPacingIntegration:
    def test_async_and_semisync_shorten_wall_clock(self, setup):
        env, model = setup
        _, led_sync, _ = crosatfl_engine(env, model).run()
        _, led_async, _ = scenario_engine("CroSatFL-Async", env, model).run()
        _, led_semi, _ = scenario_engine("CroSatFL-SemiSync", env,
                                         model).run()
        assert led_async.wall_clock_s <= led_sync.wall_clock_s
        assert led_semi.wall_clock_s <= led_sync.wall_clock_s
        # pacing only re-times the round: message counts are unchanged
        assert led_async.gs_count == led_sync.gs_count
        assert led_async.intra_lisl_count == led_sync.intra_lisl_count

    def test_semisync_straggler_fold_over_rounds(self, setup):
        env, model = setup
        eng = scenario_engine("CroSatFL-SemiSync", env, model, rounds=2,
                              quantile=0.5)
        w, led, hist = eng.run(eval_fn=lambda p, r: model.evaluate(p))
        assert len(hist) == 2
        assert all(np.isfinite(h["loss"]) for h in hist)
        assert led.total_energy_j > 0


class TestSemiSyncCheckpointResume:
    def test_resume_with_pending_straggler_is_exact(self, setup, tmp_path):
        """DESIGN.md §8 caveat, closed: SemiSyncPacing's straggler stash
        rides in SessionState.pacing_state (serialized by ckpt/store.py),
        so a semi-sync disk resume replays the uninterrupted session
        bit-for-bit even when a deferred update is pending at the
        checkpoint boundary."""
        import json

        import jax

        from repro.ckpt import load_session
        env, model = setup
        ev = lambda p, r: model.evaluate(p)   # noqa: E731
        kw = dict(rounds=4, quantile=0.5)
        w_full, led_full, hist_full = scenario_engine(
            "CroSatFL-SemiSync", env, model, **kw).run(
            eval_fn=ev, ckpt_dir=str(tmp_path / "ck"))

        with open(tmp_path / "ck" / "step_2" / "meta.json") as f:
            meta = json.load(f)
        # the whole point: a straggler IS pending at this boundary
        # (quantile=0.5 over 4 distinct cluster barriers defers two)
        assert meta["pacing_pending"], \
            "fixture must leave a deferred update pending at the boundary"

        K = len(meta["masters"])
        like = model.stack([model.init(jax.random.PRNGKey(0))] * K)
        st = load_session(str(tmp_path / "ck" / "step_2"), like)
        assert st.round_idx == 2
        assert st.pacing_state is not None
        assert sorted(st.pacing_state["pending"]) == meta["pacing_pending"]

        w_res, led_res, hist_res = scenario_engine(
            "CroSatFL-SemiSync", env, model, **kw).run(eval_fn=ev, state=st)
        assert dataclasses.asdict(led_res) == dataclasses.asdict(led_full)
        for a, b in zip(jax.tree.leaves(w_res), jax.tree.leaves(w_full)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ([h["acc"] for h in hist_res]
                == [h["acc"] for h in hist_full[2:]])

    def test_reused_engine_resume_clears_stale_stash(self, setup, tmp_path):
        """Regression: resuming on an engine whose previous run() left a
        straggler stash on the pacing policy must CLEAR it when the
        checkpoint has no pending state — a None snapshot means 'nothing
        pending', not 'keep whatever is lying around'."""
        import jax

        from repro.ckpt import load_session
        env, model = setup
        kw = dict(rounds=4, quantile=0.5)
        eng = scenario_engine("CroSatFL-SemiSync", env, model, **kw)
        eng.run(ckpt_dir=str(tmp_path / "ck"))
        assert eng.pacing._pending          # prior run left a stash behind

        K = len(eng.last_plan.clusters)
        like = model.stack([model.init(jax.random.PRNGKey(0))] * K)
        st_reused = load_session(str(tmp_path / "ck" / "step_2"), like)
        st_fresh = load_session(str(tmp_path / "ck" / "step_2"), like)
        st_reused.pacing_state = st_fresh.pacing_state = None  # no pending

        w_reused, led_reused, _ = eng.run(state=st_reused)
        w_fresh, led_fresh, _ = scenario_engine(
            "CroSatFL-SemiSync", env, model, **kw).run(state=st_fresh)
        assert (dataclasses.asdict(led_reused)
                == dataclasses.asdict(led_fresh))
        for a, b in zip(jax.tree.leaves(w_reused), jax.tree.leaves(w_fresh)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_sync_checkpoints_carry_no_pacing_payload(self, setup, tmp_path):
        """Default SyncPacing sessions keep writing pacing-free checkpoints
        (no pacing.npz, empty pending list) — byte-compatible with the
        pre-field format."""
        import json
        import os

        env, model = setup
        crosatfl_engine(env, model, rounds=2).run(
            ckpt_dir=str(tmp_path / "ck"))
        step = tmp_path / "ck" / "step_2"
        with open(step / "meta.json") as f:
            meta = json.load(f)
        assert meta["pacing_pending"] == []
        assert not os.path.exists(step / "pacing.npz")


# ---------------------------------------------------------------------------
# Gossip-only sessions
# ---------------------------------------------------------------------------

class TestGossipOnly:
    def test_no_gs_contact_at_all(self, setup):
        env, model = setup
        eng = scenario_engine("CroSatFL-Gossip", env, model)
        w, led, _ = eng.run()
        assert led.gs_count == 0
        assert led.gs_energy_j == 0.0
        assert led.train_energy_j > 0
        assert led.inter_lisl_count > 0          # flood + gossip + consensus

    def test_consensus_finalize_reports_mixing_bound(self, setup):
        env, model = setup
        eng = scenario_engine("CroSatFL-Gossip", env, model,
                              consensus_eps=1e-2)
        _, led_g, _ = eng.run()
        info = eng.mixing.last_consensus
        assert 0.0 <= info["sigma2"] < 1.0       # connected master graph
        assert 1 <= info["rounds"] <= eng.mixing.max_consensus_rounds
        # consensus rounds cost extra inter-LISL traffic vs plain CroSatFL
        env2, model2 = setup
        _, led_c, _ = crosatfl_engine(env2, model2).run()
        assert led_g.inter_lisl_count > led_c.inter_lisl_count


# ---------------------------------------------------------------------------
# Per-cluster codec maps
# ---------------------------------------------------------------------------

class TestCodecMap:
    def test_static_map_scopes_codec_per_cluster(self):
        lp = LinkParams()
        cm = CodecMap(per_cluster={1: BlockMinifloatCodec(bits=8)})
        led = EnergyLedger()
        tr = Transport(led, lp, 1e6, cm)
        assert tr.for_cluster(0) is tr           # default → same object
        assert tr.for_cluster(None) is tr
        assert tr.arith_scale_for(0) == 1.0
        assert tr.arith_scale_for(1) == 0.5
        tr.for_cluster(1).intra(1, 1e6)
        assert led.lisl_energy_j == e_lisl(1e6 * 8 / 32, lp.lisl_rate,
                                           1e6, lp)
        tr.for_cluster(0).intra(1, 1e6)          # full payload, same ledger
        assert led.intra_lisl_count == 2

    def test_hardware_aware_map_halves_cpu_cluster_energy(self, setup):
        env, model = setup
        _, led_i, _ = crosatfl_engine(env, model).run()
        eng = scenario_engine("CroSatFL-HeteroCodec", env, model)
        _, led_h, _ = eng.run()
        # the fixture (gpu_fraction=0.5) yields at least one CPU-heavy
        # cluster, so block-minifloat actually engages somewhere
        assert eng.codec.per_cluster
        # same protocol (identical message counts), cheaper energy
        assert led_h.gs_count == led_i.gs_count
        assert led_h.intra_lisl_count == led_i.intra_lisl_count
        assert led_h.inter_lisl_count == led_i.inter_lisl_count
        assert led_h.train_energy_j < led_i.train_energy_j
        assert led_h.lisl_energy_j < led_i.lisl_energy_j


# ---------------------------------------------------------------------------
# Scenario presets end-to-end
# ---------------------------------------------------------------------------

class TestScenarioPresets:
    @pytest.mark.parametrize("name", SCENARIO_NAMES)
    def test_preset_completes_with_finite_nonzero_ledger(self, setup, name):
        env, model = setup
        eng = scenario_engine(name, env, model)
        assert eng.name == name
        w, led, hist = eng.run(eval_fn=lambda p, r: model.evaluate(p))
        row = led.row()
        assert all(np.isfinite(v) for v in row.values())
        assert led.total_energy_j > 0
        assert led.train_energy_j > 0
        assert len(hist) == 1 and np.isfinite(hist[0]["loss"])
        if name == "CroSatFL-Gossip":
            assert led.gs_count == 0
        else:
            assert led.gs_count > 0


# ---------------------------------------------------------------------------
# WindowTable as an event source (DESIGN.md §11)
# ---------------------------------------------------------------------------

def _ref_windows(col, step_s, t0, horizon_s):
    """Independent reference: unroll the periodic visibility column far
    enough to cover the query plus one full period, then collect
    open/close transitions with a plain linear scan. No wrap arithmetic,
    no frontier state — deliberately the dumbest correct implementation."""
    import math
    n = len(col)
    i0 = math.ceil(t0 / step_s)
    i_end = math.ceil((t0 + horizon_s) / step_s)
    unrolled = np.tile(col, (i_end + n) // n + 2)
    out, open_t = [], None
    # ongoing pass at an off-grid t0 opens at t0 itself (next_window rule)
    i_floor = math.floor(t0 / step_s)
    for j in range(i0, i_end):
        if unrolled[j] and open_t is None:
            ongoing = j == i0 and i_floor != i0 and unrolled[i_floor]
            open_t = float(t0) if ongoing else j * step_s
        elif not unrolled[j] and open_t is not None:
            out.append((open_t, j * step_s))
            open_t = None
    if open_t is not None:
        for k in range(i_end, i_end + n):
            if not unrolled[k]:
                out.append((open_t, k * step_s))
                break
        else:
            out.append((open_t, (i_end + n) * step_s))
    return out


class TestWindowEventSource:
    @pytest.fixture(scope="class")
    def table(self):
        from repro.constellation.gs import GroundStation, WindowTable
        from repro.constellation.walker import WalkerDelta
        wd = WalkerDelta(n_planes=6, sats_per_plane=4)
        # a short table period forces the wrap-around path quickly
        return WindowTable(GroundStation(), wd, step_s=30.0,
                           horizon_s=6000.0)

    def _busy_sat(self, table):
        counts = table.vis.sum(0)
        sat = int(np.argmax(counts))
        assert counts[sat] > 0, "fixture must see at least one pass"
        assert counts[sat] < table.n_steps, "fixture must also lose it"
        return sat

    @pytest.mark.parametrize("t0", [0.0, 17.0, 5700.0, 5985.0, 12345.0])
    def test_windows_match_exact_scan_across_wraparound(self, table, t0):
        """The indexed walk (ongoing-pass rule, periodic wrap, true
        closes past the horizon) agrees with a brute-force scan of the
        unrolled visibility sequence — including t0 near and past the
        table period, where every query wraps."""
        sat = self._busy_sat(table)
        horizon = 4000.0
        got = table.windows(sat, t0, horizon)
        want = _ref_windows(table.vis[:, sat], table.step_s, t0, horizon)
        assert got == want
        for t_open, t_close in got:
            assert t0 <= t_open < t0 + horizon
            assert t_close > t_open             # closes never truncated

    def test_event_source_emits_each_pass_once(self, table):
        """Streaming the same span in two extend() calls must not
        re-report the window straddling the split (the ongoing-pass
        watermark), and open/close events must pair up exactly with the
        table's windows."""
        from repro.sim import CONTACT_CLOSE, CONTACT_OPEN, EventQueue
        from repro.sim.windows import WindowEventSource
        sat = self._busy_sat(table)
        want = table.windows(sat, 0.0, 6000.0)
        # split the span INSIDE the first window so it is ongoing at the
        # second extend's frontier
        mid = (want[0][0] + want[0][1]) / 2.0
        src = WindowEventSource(table, [sat], {sat: 0})
        q = EventQueue()
        n1 = src.extend(q, mid)
        n2 = src.extend(q, 6000.0)
        assert n1 + n2 == len(want)
        evs = q.pop_until(float("inf"))
        opens = [(ev.t, ev.payload["close_t"]) for ev in evs
                 if ev.kind == CONTACT_OPEN]
        closes = [ev.t for ev in evs if ev.kind == CONTACT_CLOSE]
        assert opens == want
        assert closes == [c for _, c in want]
        assert all(ev.sat == sat and ev.cluster == 0 for ev in evs)


# ---------------------------------------------------------------------------
# Zero-participant rounds (regression: max() on empty waits / sels[0])
# ---------------------------------------------------------------------------

class TestZeroParticipantRound:
    def test_barrier_waits_empty_returns_zero(self):
        led = EnergyLedger()
        tr = Transport(led, LinkParams(), 1e6)
        assert _GSCentricMixing()._barrier_waits(tr, []) == 0.0
        assert led.waiting_time_s == 0.0

    @pytest.mark.parametrize("mixing_cls", [GSStarMixing,
                                            RelayedGSStarMixing])
    def test_empty_selection_round_completes(self, setup, mixing_cls):
        env, model = setup
        eng = RoundEngine(
            EngineConfig(rounds=1, local_epochs=1,
                         model_bits=model.model_bits()),
            env, model,
            clustering=SingleCluster(),
            selection=TopMEnergyUtility(select_m=0),
            mixing=mixing_cls(), name="empty-round")
        w, led, _ = eng.run()
        assert led.train_energy_j == 0.0
        assert led.compute_time_s == 0.0
        assert led.waiting_time_s == 0.0
        assert led.gs_count == 0
        assert np.isfinite(led.wall_clock_s)
