"""repro.sim acceptance tests (DESIGN.md §11).

The load-bearing guarantees:

1. the event kernel's order is total and reproducible: co-timed events
   resolve by physical priority, then by a seeded tie-break that is a
   function of the kernel seed alone (never of heap internals);
2. ``EventDrivenPacing`` wrapping the default ``SyncPacing`` REPLAYS the
   lock-step session through the kernel bit-for-bit: the golden
   ``EnergyLedger`` (tests/golden_engine.json) and the plain-Session
   weights reproduce exactly, traced or untraced;
3. wrapping ``SemiSyncPacing`` preserves that policy's ledger while
   surfacing straggler overruns as STRAGGLER_TIMEOUT events;
4. ``EventAsyncPacing`` runs true per-cluster clocks: merges commit at
   LISL availability, the commit wait lands in the ledger AND the
   mirror trace with the same float, and staleness is sim-seconds.

Plus unit coverage of EventQueue / ClockSet / checkpoint round-trips.
"""
import dataclasses
import json
import os
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import EnergyLedger, LinkParams
from repro.core.session import Session
from repro.fl.engine import (EngineConfig, RoundSelection, SemiSyncPacing,
                             Transport, make_crosatfl)
from repro.fl.engine.base import EngineContext
from repro.fl.engine.pacing import AsyncPacing, weights_from_staleness
from repro.obs import TracingObserver, validate_event
from repro.sim import (CONTACT_CLOSE, CONTACT_OPEN, MERGE_COMMIT,
                       STRAGGLER_TIMEOUT, TRAIN_DONE, TRANSFER_DONE,
                       ClockSet, EventAsyncPacing, EventDrivenPacing,
                       EventQueue)

from golden_capture import build_setup, session_config, weights_digest

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_engine.json")


def assert_ledger_equal(ledger, want: dict):
    got = dataclasses.asdict(ledger)
    assert set(got) == set(want)
    for k, v in want.items():
        assert got[k] == v, (k, got[k], v)   # bit-for-bit, counts and floats


def event_engine(env, model, pacing, rounds=None, observer=None):
    """CroSatFL on the golden fixture with an event-driven pacing swap —
    everything else identical to the Session recipe test_engine_parity
    pins, so ledger comparisons isolate the pacing policy."""
    scfg = session_config(model)
    cfg = scfg.engine_config()
    if rounds is not None:
        cfg = dataclasses.replace(cfg, rounds=rounds)
    return make_crosatfl(cfg, env, model, k_nbr=scfg.k_nbr,
                         skip_one=scfg.skip_one, starmask=scfg.starmask,
                         pacing=pacing, observer=observer)


# ---------------------------------------------------------------------------
# 1. kernel units: total, reproducible order
# ---------------------------------------------------------------------------

def _fill(q: EventQueue) -> None:
    q.push(10.0, MERGE_COMMIT)
    q.push(10.0, CONTACT_OPEN, sat=3)
    q.push(10.0, CONTACT_CLOSE, sat=4)
    q.push(10.0, TRAIN_DONE, cluster=0)
    q.push(10.0, TRAIN_DONE, cluster=1)
    q.push(5.0, TRANSFER_DONE, cluster=2)


class TestEventQueue:
    def test_time_then_priority_then_seeded_tiebreak(self):
        q = EventQueue(seed=7)
        _fill(q)
        popped = q.pop_until(10.0)
        assert len(popped) == 6 and len(q) == 0
        assert popped[0].kind == TRANSFER_DONE        # earlier time wins
        # co-timed events resolve in physical order: a contact closing at
        # t is gone before one opening at t; training precedes the merge
        kinds = [ev.kind for ev in popped[1:]]
        assert kinds == [CONTACT_CLOSE, CONTACT_OPEN, TRAIN_DONE,
                         TRAIN_DONE, MERGE_COMMIT]

    def test_same_seed_reproduces_tiebreak_order(self):
        def order(seed):
            q = EventQueue(seed)
            _fill(q)
            return [(ev.kind, ev.cluster, ev.sat) for ev in q.pop_until(11.0)]
        assert order(7) == order(7)                   # deterministic
        # the two co-timed TRAIN_DONEs order by the seeded draw, so SOME
        # seed flips them (else the tie-break would be dead code)
        base = order(7)
        assert any(order(s) != base for s in range(20))

    def test_pop_until_is_inclusive(self):
        q = EventQueue()
        q.push(1.0, TRAIN_DONE, cluster=0)
        q.push(1.0 + 1e-9, TRAIN_DONE, cluster=1)
        popped = q.pop_until(1.0)
        assert [ev.cluster for ev in popped] == [0]
        assert q.peek_t() == 1.0 + 1e-9

    def test_reset_replays_the_same_stream(self):
        q = EventQueue(seed=3)
        _fill(q)
        first = [ev.kind for ev in q.pop_until(11.0)]
        q.reset()
        _fill(q)
        assert [ev.kind for ev in q.pop_until(11.0)] == first

    def test_state_roundtrip_continues_the_tiebreak_stream(self):
        q = EventQueue(seed=5)
        _fill(q)
        q.pop_until(11.0)                   # advance the tie-break RNG
        fresh = EventQueue(seed=5)
        fresh.load_state_dict(json.loads(json.dumps(q.state_dict())))
        _fill(q)
        _fill(fresh)
        assert ([(ev.kind, ev.seq) for ev in q.pop_until(11.0)]
                == [(ev.kind, ev.seq) for ev in fresh.pop_until(11.0)])

    def test_payload_carries_raw_floats(self):
        q = EventQueue()
        ev = q.push(2.5, TRAIN_DONE, cluster=1, barrier=2.5, round=0)
        assert ev.payload == {"barrier": 2.5, "round": 0}
        assert q.pop().payload["barrier"] == 2.5


class TestClockSet:
    def test_advance_is_monotone(self):
        c = ClockSet()
        c.init(0, 10.0)
        assert c.advance_to(0, 25.0) == 25.0
        assert c.advance_to(0, 5.0) == 25.0           # never rewinds
        assert c[0] == 25.0

    def test_init_is_setdefault(self):
        c = ClockSet()
        c.init("gs", 100.0)
        c.init("gs", 0.0)                             # resumed clock kept
        assert c["gs"] == 100.0

    def test_state_roundtrip_restores_int_and_str_keys(self):
        c = ClockSet()
        c.init(0, 1.5)
        c.init(3, 2.5)
        c.init("gs", 9.0)
        d = ClockSet()
        d.load_state_dict(json.loads(json.dumps(c.state_dict())))
        assert d[0] == 1.5 and d[3] == 2.5 and d["gs"] == 9.0
        assert sorted(map(str, d.names())) == sorted(map(str, c.names()))
        assert d.max([0, 3]) == 2.5


# ---------------------------------------------------------------------------
# 2. sync replay == golden, bit for bit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


class TestSyncReplayParity:
    def test_event_replay_matches_golden_and_plain_session(self, golden):
        env, model = build_setup()
        pac = EventDrivenPacing()
        w_ev, led_ev, _ = event_engine(env, model, pac).run()

        env, model = build_setup()
        w_plain, led_plain, _ = Session(session_config(model), env,
                                        model).run()

        assert_ledger_equal(led_ev, golden["CroSatFL"]["ledger"])
        assert_ledger_equal(led_ev, dataclasses.asdict(led_plain))
        assert weights_digest(w_ev) == weights_digest(w_plain)
        # the kernel actually ran: every cluster timeline moved
        assert all(pac.clocks[kc] > 0.0 for kc in pac.clocks.names()
                   if isinstance(kc, int))
        assert len(pac.kernel) == 0                   # drained each round

    def test_traced_replay_still_matches_golden(self, golden):
        """Attaching the observer streams every kernel pop through
        sim_event but must not move a single ledger bit."""
        env, model = build_setup()
        obs = TracingObserver()
        _, led, _ = event_engine(env, model, EventDrivenPacing(),
                                 observer=obs).run()
        assert_ledger_equal(led, golden["CroSatFL"]["ledger"])
        assert obs.reconcile(led)["exact"]
        sims = [e for e in obs.tracer.events if e["kind"] == "sim_event"]
        assert {e["etype"] for e in sims} >= {TRAIN_DONE, MERGE_COMMIT}
        assert [er for ev in sims for er in validate_event(ev)] == []

    def test_rerun_on_reused_engine_is_identical(self, golden):
        """A second run() on the same engine resets the kernel so the
        tie-break stream replays from the seed — no cross-run drift."""
        env, model = build_setup()
        eng = event_engine(env, model, EventDrivenPacing())
        _, led1, _ = eng.run()
        env, model = build_setup()
        eng2 = event_engine(env, model, eng.pacing)   # same pacing object
        _, led2, _ = eng2.run()
        assert_ledger_equal(led2, dataclasses.asdict(led1))
        assert_ledger_equal(led2, golden["CroSatFL"]["ledger"])


class TestSemiSyncReplay:
    def test_wrapped_semisync_preserves_ledger_and_marks_stragglers(self):
        env, model = build_setup()
        _, led_plain, _ = event_engine(
            env, model, SemiSyncPacing(quantile=0.5)).run()

        env, model = build_setup()
        obs = TracingObserver()
        _, led_ev, _ = event_engine(
            env, model, EventDrivenPacing(SemiSyncPacing(quantile=0.5)),
            observer=obs).run()
        assert_ledger_equal(led_ev, dataclasses.asdict(led_plain))
        assert obs.reconcile(led_ev)["exact"]
        # quantile=0.5 over 4 distinct cluster barriers defers stragglers
        # every round; the kernel surfaces each as a timeout event with
        # the overrun past the deadline
        touts = [e for e in obs.tracer.events
                 if e["kind"] == "sim_event"
                 and e["etype"] == STRAGGLER_TIMEOUT]
        assert touts
        assert all(e["overrun"] > 0.0 for e in touts)


# ---------------------------------------------------------------------------
# 3. EventAsyncPacing (unit level, toy vector model)
# ---------------------------------------------------------------------------

class _VecModel:
    def stack(self, params_list):
        return jnp.stack([jnp.asarray(p, jnp.float32) for p in params_list])

    def unstack(self, stacked, k):
        return [stacked[i] for i in range(k)]


def _ctx(et_full, env=None):
    led = EnergyLedger()
    return EngineContext(
        cfg=EngineConfig(), env=env, model=None,
        transport=Transport(led, LinkParams(), 1e6),
        rng=np.random.default_rng(0), tt_full=np.zeros(0),
        et_full=np.asarray(et_full, float), hw_penalty=np.zeros(0))


def _sel(tt, ids=None):
    tt = np.asarray(tt, float)
    ids = np.asarray(ids if ids is not None else np.arange(len(tt)))
    return RoundSelection(ids, np.ones(len(tt), bool), tt)


def _toy_async(pac, env=None):
    model = _VecModel()
    ctx = _ctx([1.0, 1.0], env=env)
    state = SimpleNamespace(
        round_idx=0, masters=None,
        cluster_models=model.stack([np.zeros(3), np.zeros(3)]))
    pac.bind(ctx, SimpleNamespace(n_clusters=2), state)
    return model, ctx, state


class TestEventAsyncPacing:
    def test_staleness_rule_matches_async_rank_path_at_tau_one(self):
        """The shared discount: AsyncPacing's rank formula is the tau=1
        special case, bit-identical (s/1.0 is exact)."""
        ranks = np.array([2.0, 0.0, 1.0])
        old = AsyncPacing(alpha0=0.6, decay=1.0)
        want = old.alpha0 / (1.0 + ranks) ** old.decay
        np.testing.assert_array_equal(
            weights_from_staleness(0.6, 1.0, ranks), want)

    def test_per_cluster_clocks_and_sim_second_staleness(self):
        pac = EventAsyncPacing(alpha0=0.5, decay=1.0, tau_s=1.0)
        model, ctx, state = _toy_async(pac)
        pac.begin_round(ctx, 0)
        sels = [_sel([2.0], ids=[0]), _sel([1.0], ids=[1])]
        b = [pac.account_cluster(ctx, sels[kc], kc) for kc in range(2)]
        merged = pac.merge(ctx, model, state,
                           [jnp.ones(3), jnp.ones(3)], sels, 0)
        # no geometry (env=None) -> commits at the finish times 2s / 1s;
        # staleness IS those sim-seconds, tau_s=1 -> alpha = 0.5/(1+s)
        np.testing.assert_allclose(np.asarray(merged[0]), 0.5 / 3.0)
        np.testing.assert_allclose(np.asarray(merged[1]), 0.5 / 2.0)
        assert pac.clocks[0] == 2.0 and pac.clocks[1] == 1.0
        assert pac._last_sync == {0: 2.0, 1: 1.0}
        # the wall advances to the LATEST commit, not the mean
        assert pac.advance(b) == 2.0

    def test_merge_stacked_matches_list_merge(self):
        res = {}
        for path in ("list", "stacked"):
            pac = EventAsyncPacing(alpha0=0.5, decay=1.0, tau_s=1.0)
            model, ctx, state = _toy_async(pac)
            pac.begin_round(ctx, 0)
            sels = [_sel([2.0], ids=[0]), _sel([1.0], ids=[1])]
            for kc in range(2):
                pac.account_cluster(ctx, sels[kc], kc)
            fresh = [jnp.ones(3), 2.0 * jnp.ones(3)]
            if path == "list":
                res[path] = pac.merge(ctx, model, state, fresh, sels, 0)
            else:
                res[path] = pac.merge_stacked(ctx, model, state,
                                              model.stack(fresh), sels, 0)
        np.testing.assert_array_equal(np.asarray(res["list"]),
                                      np.asarray(res["stacked"]))

    def test_merge_window_wait_hits_ledger_and_kernel(self):
        class _StubEnv:
            def next_master_contact(self, masters, kc, t0,
                                    max_wait_s=1800.0):
                return 60.0 if kc == 0 else 0.0

        pac = EventAsyncPacing(alpha0=0.5, decay=1.0, tau_s=1.0)
        model, ctx, state = _toy_async(pac, env=_StubEnv())
        state.masters = np.array([0, 1])
        pac._state = state
        pac.begin_round(ctx, 0)
        sels = [_sel([2.0], ids=[0]), _sel([1.0], ids=[1])]
        b = [pac.account_cluster(ctx, sels[kc], kc) for kc in range(2)]
        pac.merge(ctx, model, state, [jnp.ones(3), jnp.ones(3)], sels, 0)
        # cluster 0 waits 60s for a routed LISL before its commit: the
        # wait is booked, its clock lands at commit, the wall follows
        assert ctx.ledger.waiting_time_s == 60.0
        assert pac.clocks[0] == 62.0 and pac.clocks[1] == 1.0
        assert pac.advance(b) == 62.0

    def test_geom_transfer_staggers_commits(self):
        """geom_transfer=True: each commit shifts by the slant-range
        transfer duration (serialization + detoured propagation over the
        nearest other master), with NO extra ledger charge; the
        TRANSFER_DONE payload carries the duration."""
        from repro.core.energy import t_lisl

        one_ls = 299_792_458.0           # 1 light-second slant range

        class _Const:
            def pair_distance(self, i, j, t):
                return one_ls

        class _GeomEnv:
            link_params = LinkParams()
            detour = 1.2
            sat_ids = np.array([0, 1])
            constellation = _Const()

            def next_master_contact(self, masters, kc, t0,
                                    max_wait_s=1800.0):
                return 0.0

        from repro.obs.observer import EngineObserver

        class _Recorder(EngineObserver):
            def __init__(self):
                self.events = []

            def sim_event(self, kind, t, **kw):
                self.events.append((kind, t, kw))

        pac = EventAsyncPacing(alpha0=0.5, decay=1.0, tau_s=1.0,
                               geom_transfer=True)
        model, ctx, state = _toy_async(pac, env=_GeomEnv())
        state.masters = np.array([0, 1])
        pac._state = state
        rec = ctx.obs = _Recorder()
        pac.begin_round(ctx, 0)
        sels = [_sel([2.0], ids=[0]), _sel([1.0], ids=[1])]
        b = [pac.account_cluster(ctx, sels[kc], kc) for kc in range(2)]
        pac.merge(ctx, model, state, [jnp.ones(3), jnp.ones(3)], sels, 0)

        # the exact duration the driver computes: model_bits serialization
        # + detoured 1-light-second propagation
        lp = LinkParams()
        dur = float(t_lisl(ctx.cfg.model_bits, lp.lisl_rate,
                           one_ls * 1.2, lp))
        assert dur > 20.0                # ~22.35s serial + ~1.2s propagation
        assert pac.clocks[0] == 2.0 + dur
        assert pac.clocks[1] == 1.0 + dur
        assert pac.advance(b) == 2.0 + dur
        # commit shift only — the ledger books no transfer wait (comm
        # accounting stays with the engine's mixing policy)
        assert ctx.ledger.waiting_time_s == 0.0
        transfers = [(t, kw) for kind, t, kw in rec.events
                     if kind == TRANSFER_DONE]
        assert sorted(t for t, _ in transfers) == \
            sorted([1.0 + dur, 2.0 + dur])
        assert all(kw["transfer_s"] == dur for _, kw in transfers)

    def test_geom_transfer_off_keeps_legacy_payload(self):
        """Default geom_transfer=False: commits at the availability epoch
        and TRANSFER_DONE payloads carry no transfer_s key, so existing
        EventAsync traces stay byte-identical."""
        from repro.obs.observer import EngineObserver

        class _Recorder(EngineObserver):
            def __init__(self):
                self.events = []

            def sim_event(self, kind, t, **kw):
                self.events.append((kind, t, kw))

        pac = EventAsyncPacing(alpha0=0.5, decay=1.0, tau_s=1.0)
        model, ctx, state = _toy_async(pac)
        rec = ctx.obs = _Recorder()
        pac.begin_round(ctx, 0)
        sels = [_sel([2.0], ids=[0]), _sel([1.0], ids=[1])]
        for kc in range(2):
            pac.account_cluster(ctx, sels[kc], kc)
        pac.merge(ctx, model, state, [jnp.ones(3), jnp.ones(3)], sels, 0)
        assert pac.clocks[0] == 2.0 and pac.clocks[1] == 1.0
        transfers = [kw for kind, _, kw in rec.events
                     if kind == TRANSFER_DONE]
        assert transfers and all("transfer_s" not in kw
                                 for kw in transfers)

    def test_mixing_time_reenters_every_timeline(self):
        pac = EventAsyncPacing(alpha0=0.5, decay=1.0, tau_s=1.0)
        model, ctx, state = _toy_async(pac)
        pac.begin_round(ctx, 0)
        sels = [_sel([2.0], ids=[0]), _sel([1.0], ids=[1])]
        for kc in range(2):
            pac.account_cluster(ctx, sels[kc], kc)
        pac.merge(ctx, model, state, [jnp.ones(3), jnp.ones(3)], sels, 0)
        assert pac._wall_end == 2.0
        # the engine advances the wall by dt + cross-cluster mixing time;
        # the 3s mix elapses on BOTH cluster timelines at the next round
        ctx.ledger.wall_clock_s = 5.0
        pac.begin_round(ctx, 1)
        assert pac.clocks[0] == 5.0 and pac.clocks[1] == 4.0

    def test_zero_participant_generation(self):
        pac = EventAsyncPacing()
        model, ctx, state = _toy_async(pac)
        pac.begin_round(ctx, 0)
        alphas, ranks = pac._merge_weights(ctx)
        assert alphas.size == 0 and ranks.size == 0
        assert pac.advance([]) == 0.0

    def test_alpha0_validated(self):
        with pytest.raises(ValueError):
            EventAsyncPacing(alpha0=0.0)

    def test_state_roundtrip_then_none_resets(self):
        pac = EventAsyncPacing(tau_s=1.0)
        model, ctx, state = _toy_async(pac)
        pac.begin_round(ctx, 0)
        sels = [_sel([2.0], ids=[0]), _sel([1.0], ids=[1])]
        for kc in range(2):
            pac.account_cluster(ctx, sels[kc], kc)
        pac.merge(ctx, model, state, [jnp.ones(3), jnp.ones(3)], sels, 0)
        sd = json.loads(json.dumps(pac.state_dict()))   # ckpt meta round-trip
        other = EventAsyncPacing(tau_s=1.0)
        other.load_state_dict(sd)
        assert other.clocks[0] == pac.clocks[0]
        assert other._last_sync == pac._last_sync
        assert other._wall_end == pac._wall_end
        # a None snapshot means "fresh session": leftovers must clear
        other.load_state_dict(None)
        assert len(other.clocks) == 0 and other._last_sync == {}


# ---------------------------------------------------------------------------
# 4. EventAsync end-to-end on the real fixture
# ---------------------------------------------------------------------------

class TestEventAsyncIntegration:
    def test_traced_session_reconciles_bit_exact(self):
        env, model = build_setup()
        obs = TracingObserver()
        pac = EventAsyncPacing()
        w, led, hist = event_engine(env, model, pac, observer=obs).run(
            eval_fn=lambda p, r: model.evaluate(p))
        assert obs.reconcile(led)["exact"]
        assert led.total_energy_j > 0
        assert all(np.isfinite(h["loss"]) for h in hist)
        sims = [e for e in obs.tracer.events if e["kind"] == "sim_event"]
        assert {e["etype"] for e in sims} >= {TRAIN_DONE, TRANSFER_DONE,
                                              MERGE_COMMIT}
        assert [er for ev in sims for er in validate_event(ev)] == []
        # staleness is sim-seconds on the commit events
        stale = [e["staleness"] for e in sims
                 if e["etype"] == MERGE_COMMIT]
        assert stale and all(s >= 0.0 for s in stale)
        # merges wait for real LISL availability (60s epochs) somewhere
        # in a 3-round session on this geometry
        assert led.waiting_time_s > 0.0

    def test_untraced_equals_traced_ledger(self):
        """The observer path must not perturb the async timeline either
        (same guarantee test_obs pins for the sync engine)."""
        env, model = build_setup()
        _, led_plain, _ = event_engine(env, model, EventAsyncPacing()).run()
        env, model = build_setup()
        _, led_obs, _ = event_engine(env, model, EventAsyncPacing(),
                                     observer=TracingObserver()).run()
        assert_ledger_equal(led_obs, dataclasses.asdict(led_plain))
