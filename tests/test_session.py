"""Integration tests: end-to-end CroSatFL sessions, baselines, checkpoint
resume, Table-II-style accounting properties."""
import jax
import numpy as np
import pytest

from repro.constellation import ConstellationEnv
from repro.core.session import Session, SessionConfig
from repro.core.starmask import StarMaskParams
from repro.data.synth import dirichlet_partition, make_dataset
from repro.fl.baselines import BASELINES, BaselineConfig
from repro.fl.client import ImageFLModel


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("eurosat-sim", n=600, seed=0)
    test = make_dataset("eurosat-sim", n=200, seed=99)
    n_clients = 8
    parts = dirichlet_partition(ds.y, n_clients, alpha=100.0, seed=0)
    env = ConstellationEnv(
        n_clients=n_clients,
        n_samples=np.array([len(p) for p in parts], float), seed=0)
    model = ImageFLModel(ds, parts, test)
    return env, model


def run_session(env, model, rounds=3, local_epochs=1, **kw):
    cfg = SessionConfig(edge_rounds=rounds, local_epochs=local_epochs,
                        k_nbr=2, model_bits=model.model_bits(),
                        starmask=StarMaskParams(k_max=4, m_min=2), **kw)
    sess = Session(cfg, env, model)
    return sess.run(eval_fn=lambda p, r: model.evaluate(p))


class TestCroSatFLSession:
    def test_session_completes_and_learns(self, setup):
        env, model = setup
        w, ledger, hist = run_session(env, model, rounds=6,
                                      local_epochs=2)
        accs = [h["acc"] for h in hist]
        # clearly better than 10% chance and improving over the session
        assert accs[-1] > 0.18
        assert accs[-1] >= accs[0]
        assert all(np.isfinite(h["loss"]) for h in hist)

    def test_gs_off_critical_path(self, setup):
        """GS comms = 2 x K (bootstrap + collect), independent of R."""
        env, model = setup
        _, led3, _ = run_session(env, model, rounds=2)
        _, led6, _ = run_session(env, model, rounds=5)
        assert led3.gs_count == led6.gs_count
        # intra-cluster LISL grows with rounds instead
        assert led6.intra_lisl_count > led3.intra_lisl_count

    def test_energy_finite_and_positive(self, setup):
        env, model = setup
        _, ledger, _ = run_session(env, model, rounds=3)
        row = ledger.row()
        for k in ("tx_energy_kj", "train_energy_kj", "tx_time_h",
                  "waiting_h"):
            assert np.isfinite(row[k]) and row[k] >= 0, (k, row[k])
        assert ledger.inter_lisl_count > 0       # random-k actually mixed

    def test_checkpoint_resume_exact(self, setup, tmp_path):
        """A session checkpointed at round r and resumed matches the
        uninterrupted run (fault-tolerance contract)."""
        from repro.ckpt import load_session, save_session
        env, model = setup
        cfg = SessionConfig(edge_rounds=4, local_epochs=1, k_nbr=2,
                            model_bits=model.model_bits(),
                            starmask=StarMaskParams(k_max=4, m_min=2))
        # full run
        s1 = Session(cfg, env, model)
        w_full, led_full, _ = s1.run()
        # interrupted run: stop at 2, checkpoint, restore, continue
        s2 = Session(cfg, env, model)
        state = None
        w_half, led_half, _ = s2.run(rounds=2)
        # emulate restart via ckpt: the controller exposes its state by
        # running with an explicit state object
        # (simpler API check: save/load state pytree fidelity)
        from repro.core.session import SessionState
        from repro.core.skipone import SkipOneState
        import jax.numpy as jnp
        st = SessionState(2, {"w": jnp.arange(6.0).reshape(2, 3)},
                          [SkipOneState.init(3)], np.array([0, 1]),
                          jax.random.PRNGKey(7), led_half)
        save_session(st, str(tmp_path / "ck"))
        st2 = load_session(str(tmp_path / "ck"), st.cluster_models)
        assert st2.round_idx == 2
        np.testing.assert_array_equal(np.asarray(st2.cluster_models["w"]),
                                      np.asarray(st.cluster_models["w"]))
        np.testing.assert_array_equal(np.asarray(st2.rng_key),
                                      np.asarray(st.rng_key))
        assert st2.ledger.gs_count == led_half.gs_count

    def test_resume_replays_uninterrupted_run_bitwise(self, setup, tmp_path):
        """Regression: a resumed session must reproduce the uninterrupted
        session's ledger, weights and history BIT-FOR-BIT. SessionState
        used to round-trip only the JAX ``rng_key``; the host numpy RNG
        (selection jitter, cross-agg group sampling, top-m noise) silently
        re-seeded on resume and the session diverged. Both RNG streams now
        ride in the checkpoint (``rng_state``)."""
        import dataclasses
        import json

        from repro.ckpt import load_session
        env, model = setup
        cfg = SessionConfig(edge_rounds=4, local_epochs=1, k_nbr=2,
                            model_bits=model.model_bits(),
                            starmask=StarMaskParams(k_max=4, m_min=2))
        ev = lambda p, r: model.evaluate(p)   # noqa: E731
        w_full, led_full, hist_full = Session(cfg, env, model).run(
            eval_fn=ev, ckpt_dir=str(tmp_path / "ck"))

        with open(tmp_path / "ck" / "step_2" / "meta.json") as f:
            meta = json.load(f)
        assert meta["host_rng"] is not None        # bit-generator persisted
        K = len(meta["masters"])
        like = model.stack([model.init(jax.random.PRNGKey(0))] * K)
        st = load_session(str(tmp_path / "ck" / "step_2"), like)
        assert st.round_idx == 2 and st.rng_state is not None

        w_res, led_res, hist_res = Session(cfg, env, model).run(
            eval_fn=ev, state=st)
        assert dataclasses.asdict(led_res) == dataclasses.asdict(led_full)
        for a, b in zip(jax.tree.leaves(w_res), jax.tree.leaves(w_full)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ([h["acc"] for h in hist_res]
                == [h["acc"] for h in hist_full[2:]])


class TestBaselines:
    @pytest.mark.parametrize("name", list(BASELINES))
    def test_baseline_runs(self, setup, name):
        env, model = setup
        cfg = BaselineConfig(rounds=2, local_epochs=1,
                             model_bits=model.model_bits())
        eng = BASELINES[name](cfg, env, model)
        w, ledger, hist = eng.run(eval_fn=lambda p, r: model.evaluate(p))
        assert len(hist) == 2
        assert ledger.total_energy_j > 0

    def test_crosatfl_beats_fedsyn_on_gs(self, setup):
        """Headline claim: orders of magnitude fewer GS comms."""
        env, model = setup
        rounds = 4
        _, led_c, _ = run_session(env, model, rounds=rounds)
        cfg = BaselineConfig(rounds=rounds, local_epochs=1,
                             model_bits=model.model_bits())
        _, led_f, _ = BASELINES["FedSyn"](cfg, env, model).run()
        # FedSyn: 2*n*R GS contacts; CroSatFL: 2*K, R-independent — the
        # ratio grows linearly in R (178x at the paper's R=40, n=40, K=9)
        assert led_f.gs_count == 2 * env.n_clients * rounds
        assert led_c.gs_count <= 2 * 4            # 2*K, K <= k_max=4
        assert led_f.gs_count >= 2 * rounds * led_c.gs_count / 4
        assert led_f.gs_energy_j > 3 * led_c.gs_energy_j

    def test_fedorbit_cheaper_than_fedscs(self, setup):
        env, model = setup
        cfg = BaselineConfig(rounds=2, local_epochs=1,
                             model_bits=model.model_bits())
        _, led_s, _ = BASELINES["FedSCS"](cfg, env, model).run()
        _, led_o, _ = BASELINES["FedOrbit"](cfg, env, model).run()
        assert led_o.transmission_energy_j < led_s.transmission_energy_j
        assert led_o.train_energy_j < led_s.train_energy_j


class TestFaultTolerance:
    def test_master_migration_on_link_loss(self, setup):
        """When the designated master becomes unreachable mid-session the
        cluster re-designates a member and the session completes (paper
        §III-A: 'the new master continues from the latest cluster model')."""
        env, model = setup
        orig = env.lisl_distance
        cut_after = {"n": 0}

        def flaky(i, j, t):
            cut_after["n"] += 1
            # cut every 7th link query to force migrations
            if cut_after["n"] % 7 == 0:
                return float("inf")
            return orig(i, j, t)

        env2 = type(env).__new__(type(env))
        env2.__dict__.update(env.__dict__)
        env2.lisl_distance = flaky
        w, ledger, hist = run_session(env2, model, rounds=3)
        assert ledger.intra_lisl_count > 0
        assert all(np.isfinite(v) for v in
                   [ledger.total_energy_j, ledger.waiting_time_s])

    def test_elastic_cluster_count(self, setup):
        """Mixing matrices are built for the observed K each round — a
        session with a different K_max (elastic re-clustering) still runs
        from the same model code."""
        env, model = setup
        from repro.core.session import Session, SessionConfig
        from repro.core.starmask import StarMaskParams
        for k_max in (3, 5):
            cfg = SessionConfig(edge_rounds=2, local_epochs=1, k_nbr=2,
                                model_bits=model.model_bits(),
                                starmask=StarMaskParams(k_max=k_max, m_min=2))
            w, ledger, _ = Session(cfg, env, model).run()
            assert ledger.inter_lisl_count >= 0
