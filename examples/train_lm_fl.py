"""End-to-end driver (deliverable b): federated training of a ~100M-class
LM with the CroSatFL protocol at the datacenter layer — K simulated
clusters, Skip-One participation masks, random-k mixing every round, and
periodic checkpointing.

    PYTHONPATH=src python examples/train_lm_fl.py --steps 300 \
        [--arch xlstm-125m] [--d-model 256] [--resume]

On this CPU container the default reduced width trains a few hundred steps
in minutes; at full width (--d-model 768 etc.) the same script is the
launcher you would run on a TPU slice (the step functions are the exact
ones the multi-pod dry-run compiles).
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import load_pytree, save_pytree
from repro.configs.base import get_config
from repro.core import crossagg
from repro.data.synth import SynthLMDataset
from repro.launch import steps as S
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.obs import get_logger

log = get_logger("examples.train_lm_fl")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--mix-every", type=int, default=10)
    ap.add_argument("--skip-prob", type=float, default=0.1)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="results/lm_fl_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        d_model=args.d_model, head_dim=args.d_model // 4,
        d_ff=args.d_model * 2 if get_config(args.arch).d_ff else 0,
        vocab_size=256)
    n_params = api.count_params(cfg)
    log.info(f"arch={args.arch} reduced to {n_params/1e6:.1f}M params, "
             f"K={args.clusters} clusters")

    K = args.clusters
    data = SynthLMDataset.make(n=K * 512, seq=args.seq + 1, vocab=256,
                               seed=0)
    shards = np.split(data.tokens, K)           # one stream per cluster
    n_samples = jnp.asarray([len(s) for s in shards], jnp.float32)

    mesh = make_test_mesh(multi_pod=True)   # clustered step needs a pod axis
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, K)
    cluster_params = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[api.init(cfg, k) for k in ks])
    mom = jax.tree.map(lambda p: jnp.zeros_like(p), cluster_params)

    step_fn = jax.jit(S.build_fl_train_step(cfg, mesh, clustered=True,
                                            lr=3e-2))
    start = 0
    if args.resume and os.path.exists(os.path.join(args.ckpt_dir, "p.npz")):
        cluster_params = load_pytree(os.path.join(args.ckpt_dir, "p.npz"),
                                     cluster_params)
        mom = load_pytree(os.path.join(args.ckpt_dir, "m.npz"), mom)
        start = int(np.load(os.path.join(args.ckpt_dir, "step.npy")))
        log.info(f"resumed from step {start}")

    rng = np.random.default_rng(0)
    t0 = time.time()
    with mesh:
        for it in range(start, args.steps):
            batch_tok = np.stack([
                s[rng.integers(0, len(s), args.batch)] for s in shards])
            batch = {
                "tokens": jnp.asarray(batch_tok[:, :, :-1]),
                "labels": jnp.asarray(batch_tok[:, :, 1:]),
                # Skip-One at the datacenter layer: zero-weight a random
                # straggler's shard occasionally
                "weights": jnp.asarray(
                    (rng.random((K, args.batch)) > args.skip_prob)
                    .astype(np.float32)),
            }
            if it % args.mix_every == args.mix_every - 1:
                reach = np.ones((K, K), bool)
                M = crossagg.mixing_matrix(
                    crossagg.sample_groups(reach, 1, rng),
                    np.asarray(n_samples))
            else:
                M = np.eye(K)
            cluster_params, mom, losses = step_fn(
                cluster_params, mom, batch, jnp.asarray(M, jnp.float32))
            if it % 20 == 0 or it == args.steps - 1:
                log.info(f"step {it:4d} losses="
                         f"{[f'{float(l):.3f}' for l in losses]} "
                         f"({time.time()-t0:.0f}s)")
            if it % args.ckpt_every == args.ckpt_every - 1:
                os.makedirs(args.ckpt_dir, exist_ok=True)
                save_pytree(cluster_params,
                            os.path.join(args.ckpt_dir, "p.npz"))
                save_pytree(mom, os.path.join(args.ckpt_dir, "m.npz"))
                np.save(os.path.join(args.ckpt_dir, "step.npy"), it + 1)

    final = crossagg.consolidate(cluster_params, n_samples)
    log.info(f"consolidated final model: "
             f"{sum(l.size for l in jax.tree.leaves(final))/1e6:.1f}M "
             f"params")
    log.info("done.")


if __name__ == "__main__":
    main()
