"""Constellation explorer: inspect the Walker-Delta substrate, LISL
topology dynamics, StarMask clustering decisions, and the paper's four
LISL-range settings (659/1319/1500/1700 km -> max cluster sizes).

    PYTHONPATH=src python examples/constellation_explorer.py
"""
import jax
import numpy as np

from repro.constellation.lisl import (LISLConfig, RANGE_SETTINGS_KM,
                                      lisl_graph)
from repro.constellation.sim import ConstellationEnv
from repro.constellation.walker import WalkerDelta
from repro.core.starmask import (Instance, StarMaskParams, cluster,
                                 effective_capacity, k_min)
from repro.obs import get_logger

log = get_logger("examples.constellation_explorer")


def main():
    w = WalkerDelta()
    log.info(f"Walker-Delta: {w.n_planes} planes x {w.sats_per_plane} sats, "
             f"{w.altitude_m/1e3:.0f} km, {w.inclination_deg:.0f} deg incl., "
             f"period {w.period_s/60:.1f} min")

    log.raw("\nLISL range sweep (paper Table I ranges):")
    for km in RANGE_SETTINGS_KM:
        cfg = LISLConfig(range_m=km * 1e3, fanout_default=10)
        adj = lisl_graph(w, 0.0, cfg)
        deg = adj.sum(1)
        log.raw(f"  {km:5d} km: mean degree {deg.mean():5.2f}, "
                f"max {deg.max():2d} -> supports clusters of "
                f"~{deg.max() + 1}")

    log.raw("\nTopology dynamics over one orbit:")
    env = ConstellationEnv(n_clients=20, seed=0)
    for frac in (0.0, 0.25, 0.5):
        t = frac * w.period_s
        a = env.client_adjacency(t)
        log.raw(f"  t={t/60:6.1f} min: client reach degree "
                f"{a.sum(1).mean():.1f}")

    log.raw("\nStarMask clustering on 20 clients:")
    rng = np.random.default_rng(0)
    n = 20
    inst = Instance(
        share=rng.dirichlet(np.ones(n)),
        hw=rng.integers(0, 2, n),
        t_comp=rng.lognormal(2.0, 0.6, n),
        e_train=rng.lognormal(4.0, 0.5, n),
        fanout=np.asarray(env.fanout),
        lisl_e=rng.uniform(1, 5, (n, n)))
    p = StarMaskParams(k_max=8, m_min=2)
    log.raw(f"  K_min (Eq. 25) = {k_min(inst, p)}")
    res = cluster(inst, p, jax.random.PRNGKey(0), n_samples=6)
    log.raw(f"  feasible={res.feasible} K={len(res.clusters)} "
            f"reward={res.reward:.4f} fallback={res.used_fallback}")
    cap = effective_capacity(inst, p)
    for i, c in enumerate(res.clusters):
        hw = "".join("G" if inst.hw[j] else "C" for j in c)
        log.raw(f"  cluster {i}: n={len(c):2d} hw={hw:10s} "
                f"t_comp range [{inst.t_comp[c].min():5.1f},"
                f"{inst.t_comp[c].max():5.1f}]s "
                f"cap={cap[c].max()+1}")
    log.raw("  (Eq. 23 master feasibility: every |C_k| <= max member cap)")


if __name__ == "__main__":
    main()
