"""Quickstart: one CroSatFL session on a small simulated constellation.

    PYTHONPATH=src python examples/quickstart.py

Walks the full paper pipeline in ~2 minutes on CPU:
  1. build a Walker-Delta constellation env with 12 FL clients,
  2. StarMask clusters them (RL policy + greedy fallback),
  3. run 5 edge rounds of on-orbit training with Skip-One and random-k
     cross-aggregation,
  4. consolidate on orbit (Eq. 38) and print the Table-II-style ledger.
"""
import numpy as np

from repro.constellation import ConstellationEnv
from repro.core.session import Session, SessionConfig
from repro.core.starmask import StarMaskParams
from repro.data.synth import dirichlet_partition, make_dataset
from repro.fl.client import ImageFLModel
from repro.obs import get_logger

log = get_logger("examples.quickstart")


def main():
    log.info("== CroSatFL quickstart ==")
    ds = make_dataset("eurosat-sim", n=1200, seed=0)
    test = make_dataset("eurosat-sim", n=400, seed=99)
    n_clients = 12
    parts = dirichlet_partition(ds.y, n_clients, alpha=0.5, seed=0)
    env = ConstellationEnv(
        n_clients=n_clients,
        n_samples=np.array([len(p) for p in parts], float), seed=0)
    model = ImageFLModel(ds, parts, test)

    cfg = SessionConfig(edge_rounds=5, local_epochs=2, k_nbr=2,
                        model_bits=model.model_bits(),
                        starmask=StarMaskParams(k_max=5, m_min=2))
    session = Session(cfg, env, model)
    w_final, ledger, history = session.run(
        eval_fn=lambda p, r: model.evaluate(p))

    log.raw("\nround  acc    loss")
    for h in history:
        log.raw(f"{h['round']:5d}  {h['acc']:.3f}  {h['loss']:.3f}")

    log.raw("\nsession ledger (Table-II shape):")
    for k, v in ledger.row().items():
        log.raw(f"  {k:16s} {v:10.3f}" if isinstance(v, float)
                else f"  {k:16s} {v:10d}")
    log.info(f"final accuracy: {model.evaluate(w_final)['acc']:.3f}")
    log.info(f"GS was contacted {ledger.gs_count} times total "
             "(bootstrap + final collection only).")


if __name__ == "__main__":
    main()
