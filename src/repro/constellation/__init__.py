"""Orbital substrate: Walker-Delta geometry, LISL graph, GS windows,
hardware heterogeneity, and the simulation env for the session controller."""
from repro.constellation.sim import ConstellationEnv  # noqa: F401
from repro.constellation.walker import WalkerDelta  # noqa: F401
