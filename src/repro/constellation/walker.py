"""Walker-Delta constellation geometry (paper Table I).

720 LEO satellites: 36 orbital planes x 20 satellites, 570 km altitude,
70 deg inclination (Starlink-like shell). Circular orbits; positions are
computed in ECI with standard rotation composition

    r(t) = Rz(RAAN_p) @ Rx(incl) @ [R cos u, R sin u, 0]

with argument of latitude u = u0 + n t, mean motion n = sqrt(mu / R^3).
Walker phasing: in-plane spacing 360/20 = 18 deg; inter-plane phase offset
F * 360 / 720 per plane (relative spacing between adjacent planes).

Vectorized numpy — the simulation is host-side orchestration.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

MU_EARTH = 3.986004418e14        # m^3/s^2
R_EARTH = 6_371_000.0            # m
OMEGA_EARTH = 7.2921159e-5       # rad/s


@dataclass(frozen=True)
class WalkerDelta:
    n_planes: int = 36
    sats_per_plane: int = 20
    altitude_m: float = 570_000.0
    inclination_deg: float = 70.0
    phasing_f: int = 1           # Walker F in [0, n_planes)

    @property
    def n_sats(self) -> int:
        return self.n_planes * self.sats_per_plane

    @property
    def radius_m(self) -> float:
        return R_EARTH + self.altitude_m

    @property
    def mean_motion(self) -> float:
        return float(np.sqrt(MU_EARTH / self.radius_m ** 3))

    @property
    def period_s(self) -> float:
        return 2 * np.pi / self.mean_motion

    def plane_of(self, sat: np.ndarray | int):
        return np.asarray(sat) // self.sats_per_plane

    def positions(self, t: float | np.ndarray) -> np.ndarray:
        """ECI positions (..., n_sats, 3) in meters at time(s) t (s)."""
        t = np.asarray(t, np.float64)
        squeeze = t.ndim == 0
        t = np.atleast_1d(t)

        p = np.arange(self.n_planes)
        s = np.arange(self.sats_per_plane)
        raan = 2 * np.pi * p / self.n_planes                       # (P,)
        u0 = (2 * np.pi * s[None, :] / self.sats_per_plane
              + 2 * np.pi * self.phasing_f * p[:, None] / self.n_sats)  # (P,S)
        u = u0[None] + self.mean_motion * t[:, None, None]         # (T,P,S)

        inc = np.deg2rad(self.inclination_deg)
        cu, su = np.cos(u), np.sin(u)
        # orbital-plane coords -> ECI
        x_orb, y_orb = cu, su
        x_i = x_orb
        y_i = y_orb * np.cos(inc)
        z_i = y_orb * np.sin(inc)
        cr, sr = np.cos(raan), np.sin(raan)                        # (P,)
        x = cr[None, :, None] * x_i - sr[None, :, None] * y_i
        y = sr[None, :, None] * x_i + cr[None, :, None] * y_i
        z = z_i
        pos = np.stack([x, y, z], -1).reshape(t.shape[0], self.n_sats, 3)
        pos = pos * self.radius_m
        return pos[0] if squeeze else pos

    def pairwise_distances(self, t: float) -> np.ndarray:
        """(n_sats, n_sats) meters at time t."""
        pos = self.positions(t)
        diff = pos[:, None, :] - pos[None, :, :]
        return np.linalg.norm(diff, axis=-1)

    def subset_positions(self, sats: np.ndarray | list,
                         t: float | np.ndarray) -> np.ndarray:
        """ECI positions (..., len(sats), 3) for a subset of satellites.

        Same rotation composition as ``positions`` restricted to the
        requested ids — scanning a long horizon for a handful of masters
        (the event kernel's window iteration) stays O(T x M), not
        O(T x n_sats)."""
        t = np.asarray(t, np.float64)
        squeeze = t.ndim == 0
        t = np.atleast_1d(t)
        sats = np.atleast_1d(np.asarray(sats, int))
        p = sats // self.sats_per_plane
        s = sats % self.sats_per_plane
        raan = 2 * np.pi * p / self.n_planes                        # (M,)
        u0 = (2 * np.pi * s / self.sats_per_plane
              + 2 * np.pi * self.phasing_f * p / self.n_sats)       # (M,)
        u = u0[None, :] + self.mean_motion * t[:, None]             # (T,M)

        inc = np.deg2rad(self.inclination_deg)
        cu, su = np.cos(u), np.sin(u)
        x_i = cu
        y_i = su * np.cos(inc)
        z_i = su * np.sin(inc)
        cr, sr = np.cos(raan), np.sin(raan)                         # (M,)
        x = cr[None, :] * x_i - sr[None, :] * y_i
        y = sr[None, :] * x_i + cr[None, :] * y_i
        pos = np.stack([x, y, z_i], -1) * self.radius_m             # (T,M,3)
        return pos[0] if squeeze else pos

    def pair_distance(self, i: int, j: int,
                      t: float | np.ndarray) -> np.ndarray:
        """|r_i - r_j| in meters at time(s) t, without forming all
        n_sats positions — the LISL contact-window scan for one master
        pair calls this over thousands of grid points."""
        pos = self.subset_positions([int(i), int(j)], t)
        return np.linalg.norm(pos[..., 0, :] - pos[..., 1, :], axis=-1)
