"""Time-varying LISL topology (paper §III-A/B).

A LISL {i, j} exists at time t when the inter-satellite distance is within
the communication range AND the line of sight clears the Earth's limb.
Per-satellite fan-out limits c_i cap the degree: when more neighbors are in
range than c_i allows, the closest c_i are kept (laser terminals must be
pointed; nearest neighbors have the most stable geometry).

Paper range settings: 659 / 1319 / 1500 / 1700 km -> max cluster sizes
~2 / 4 / 6 / 10.

Rate model: constant allocated bandwidth (Table I) — geometry enters via
the propagation latency; the paper's Eq. 5 treats R_ij(t) as instantaneous
rate, which we expose as ``rate(i, j, t)`` for extensibility.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.constellation.walker import R_EARTH, WalkerDelta

RANGE_SETTINGS_KM = (659, 1319, 1500, 1700)   # paper §V-A
ATMOSPHERE_M = 80_000.0                        # grazing-height margin


@dataclass(frozen=True)
class LISLConfig:
    range_m: float = 1_500_000.0
    fanout_default: int = 4
    rate_bps: float = 16e6       # Table I data rate


def earth_blocked(pos_i: np.ndarray, pos_j: np.ndarray,
                  limb_m: float = R_EARTH + ATMOSPHERE_M) -> np.ndarray:
    """True where the i-j segment dips below the limb radius."""
    d = pos_j - pos_i
    dd = (d * d).sum(-1)
    tt = -(pos_i * d).sum(-1) / np.maximum(dd, 1e-9)
    tt = np.clip(tt, 0.0, 1.0)
    closest = pos_i + tt[..., None] * d
    return (closest * closest).sum(-1) < limb_m ** 2


def lisl_graph(constellation: WalkerDelta, t: float, cfg: LISLConfig,
               fanout: np.ndarray | None = None,
               subset: np.ndarray | None = None) -> np.ndarray:
    """(n, n) bool adjacency at time t (fan-out capped, symmetric AND).

    subset: restrict to these satellite ids (returns (len, len))."""
    pos = constellation.positions(t)
    if subset is not None:
        pos = pos[subset]
    n = pos.shape[0]
    diff = pos[:, None, :] - pos[None, :, :]
    dist = np.linalg.norm(diff, axis=-1)
    in_range = (dist < cfg.range_m) & ~np.eye(n, dtype=bool)
    blocked = earth_blocked(pos[:, None, :], pos[None, :, :])
    adj = in_range & ~blocked

    fo = (np.full(n, cfg.fanout_default) if fanout is None
          else np.asarray(fanout))
    # keep the closest c_i neighbors per satellite, then require mutuality
    keep = np.zeros_like(adj)
    big = np.where(adj, dist, np.inf)
    order = np.argsort(big, axis=1)
    for i in range(n):
        nbrs = order[i][: fo[i]]
        nbrs = nbrs[np.isfinite(big[i, nbrs])]
        keep[i, nbrs] = True
    return keep & keep.T


def distance_matrix(constellation: WalkerDelta, t: float,
                    subset: np.ndarray | None = None) -> np.ndarray:
    pos = constellation.positions(t)
    if subset is not None:
        pos = pos[subset]
    return np.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=-1)


def reachable(adj: np.ndarray, hops: int = 1) -> np.ndarray:
    """Multi-hop reachability (master graph is rarely 1-hop connected)."""
    r = adj.copy()
    cur = adj.copy()
    for _ in range(hops - 1):
        cur = (cur.astype(int) @ adj.astype(int)) > 0
        r |= cur
    np.fill_diagonal(r, False)
    return r
