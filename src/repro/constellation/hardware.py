"""On-board hardware heterogeneity profiles (paper §V: Spiral Blue Space
Edge One traces; 50% CPU-only, 50% GPU-equipped).

The public traces give order-of-magnitude throughput for space-rated edge
hardware: CPU-class boards sustain a few GFLOP/s on CNN training, Jetson-
class GPU payloads tens of GFLOP/s at ~15-30 W. We model

    alpha_CPU ~ lognormal(mean 4 GFLOP/s,  sigma 0.3)
    alpha_GPU ~ lognormal(mean 40 GFLOP/s, sigma 0.3)

giving the ~10x CPU/GPU per-epoch gap the paper's Fig. 5 exercises.
"""
from __future__ import annotations

import numpy as np

from repro.core.energy import CPU, GPU, HardwareProfile

ALPHA_CPU = 4e9      # effective FLOP/s, CPU-only satellite
ALPHA_GPU = 40e9     # GPU-equipped satellite


def make_profiles(n: int, gpu_fraction: float = 0.5,
                  rng: np.random.Generator | None = None,
                  ) -> list[HardwareProfile]:
    rng = rng or np.random.default_rng(0)
    n_gpu = int(round(n * gpu_fraction))
    kinds = np.array([GPU] * n_gpu + [CPU] * (n - n_gpu))
    rng.shuffle(kinds)
    profiles = []
    for k in kinds:
        jitter = rng.lognormal(0.0, 0.3)
        if k == GPU:
            profiles.append(HardwareProfile(
                hw_type=GPU, alpha=ALPHA_GPU * jitter,
                gpu_power=rng.uniform(20.0, 35.0)))
        else:
            freq = rng.uniform(1.2e9, 1.8e9)
            profiles.append(HardwareProfile(
                hw_type=CPU, alpha=ALPHA_CPU * jitter,
                cycles_per_sample=4e7, freq=freq, kappa=1e-27))
    return profiles


def fanout_for_range(range_m: float) -> int:
    """Paper §V-A: ranges 659/1319/1500/1700 km support max cluster sizes
    ~2/4/6/10 — fan-out = cluster size - 1 seen by the master, but members
    also need links; we cap per-satellite degree at the cluster size."""
    km = range_m / 1e3
    if km <= 700:
        return 2
    if km <= 1350:
        return 4
    if km <= 1550:
        return 6
    return 10
