"""Ground-station visibility (paper §III-B, Canberra GS).

GS at latitude -35.40139, longitude 148.98167 (paper §V-A). The GS position
rotates with the Earth in ECI; a satellite is visible when its elevation
above the local horizon exceeds the mask angle.

``next_window`` scans forward in time for the next visibility window —
the paper's "waiting time" for GS-bound transfers comes from here.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.constellation.walker import OMEGA_EARTH, R_EARTH, WalkerDelta

CANBERRA_LAT = -35.40139
CANBERRA_LON = 148.98167


@dataclass(frozen=True)
class GroundStation:
    lat_deg: float = CANBERRA_LAT
    lon_deg: float = CANBERRA_LON
    elevation_mask_deg: float = 10.0
    rate_bps: float = 8e6

    def position(self, t: float | np.ndarray) -> np.ndarray:
        """ECI position (…, 3); Earth rotation carries the GS eastward."""
        t = np.asarray(t, np.float64)
        lat = np.deg2rad(self.lat_deg)
        lon = np.deg2rad(self.lon_deg) + OMEGA_EARTH * t
        clat = np.cos(lat)
        return R_EARTH * np.stack(
            [clat * np.cos(lon), clat * np.sin(lon),
             np.full_like(np.asarray(lon, np.float64), np.sin(lat))], -1)

    def elevation(self, sat_pos: np.ndarray, t: float | np.ndarray) -> np.ndarray:
        """Elevation angle (deg) of satellite(s) above the GS horizon."""
        gs = self.position(t)
        rel = sat_pos - gs
        up = gs / np.linalg.norm(gs, axis=-1, keepdims=True)
        rng = np.linalg.norm(rel, axis=-1)
        sin_el = (rel * up).sum(-1) / np.maximum(rng, 1e-9)
        return np.rad2deg(np.arcsin(np.clip(sin_el, -1.0, 1.0)))

    def visible(self, sat_pos: np.ndarray, t: float | np.ndarray) -> np.ndarray:
        return self.elevation(sat_pos, t) > self.elevation_mask_deg

    def slant_range(self, sat_pos: np.ndarray, t: float | np.ndarray) -> np.ndarray:
        return np.linalg.norm(sat_pos - self.position(t), axis=-1)

    def next_window(self, constellation: WalkerDelta, sat: int, t0: float,
                    step_s: float = 30.0, horizon_s: float = 86_400.0,
                    ) -> tuple[float, float]:
        """(wait_s, slant_range_m at contact) for satellite ``sat`` from t0.

        Scans forward in ``step_s`` increments (a 570 km pass lasts minutes,
        so 30 s resolution is adequate for the energy model)."""
        ts = t0 + np.arange(0.0, horizon_s, step_s)
        pos = constellation.positions(ts)[:, sat, :]
        vis = self.visible(pos, ts)
        idx = np.argmax(vis)
        if not vis[idx]:
            # no contact in horizon: report horizon as wait, nominal range
            return horizon_s, 2_000_000.0
        return float(ts[idx] - t0), float(self.slant_range(pos[idx], ts[idx]))


class WindowTable:
    """Precomputed GS-visibility table for fast repeated window queries.

    Baselines query ``next_window`` thousands of times (per client, per
    round); scanning the orbit each time is O(horizon) per call. This
    precomputes visibility + slant range on a ``step_s`` grid over one
    table period and answers queries by index arithmetic, wrapping
    periodically (the constellation/GS geometry repeats on the order of
    the orbital/ground-track period; the wrap approximation only affects
    the tail of multi-day sessions).
    """

    def __init__(self, gs: GroundStation, constellation: WalkerDelta,
                 step_s: float = 30.0, horizon_s: float = 86_400.0):
        self.gs, self.step_s, self.horizon_s = gs, step_s, horizon_s
        ts = np.arange(0.0, horizon_s, step_s)
        pos = constellation.positions(ts)                    # (T, n, 3)
        gp = gs.position(ts)[:, None, :]                     # (T, 1, 3)
        rel = pos - gp
        rng = np.linalg.norm(rel, axis=-1)
        up = gp / np.linalg.norm(gp, axis=-1, keepdims=True)
        sin_el = (rel * up).sum(-1) / np.maximum(rng, 1e-9)
        el = np.rad2deg(np.arcsin(np.clip(sin_el, -1, 1)))
        self.vis = el > gs.elevation_mask_deg                # (T, n)
        self.rng = rng.astype(np.float32)
        self.n_steps = len(ts)

    def next_window(self, sat: int, t0: float) -> tuple[float, float]:
        # Waits are measured from t0 itself against the first grid sample
        # at/after t0 — the old floored lookup overestimated every wait by
        # up to step_s and reported wait=0 with a stale slant range for a
        # pass that ended mid-step. A pass is ONGOING at an off-grid t0
        # only when the samples on both sides are visible; then the wait
        # really is zero (range taken at the next sample, still in-pass).
        i0 = int(np.ceil(t0 / self.step_s))
        start0 = i0 % self.n_steps
        col_v = self.vis[:, sat]
        col_r = self.rng[:, sat]
        i_floor = int(np.floor(t0 / self.step_s))
        if i_floor != i0 and col_v[i_floor % self.n_steps] and col_v[start0]:
            return 0.0, float(col_r[start0])
        for wrap in range(2):
            seg = col_v[start0:] if wrap == 0 else col_v
            hit = int(np.argmax(seg))
            if seg[hit]:
                if wrap == 0:
                    j, idx = i0 + hit, start0 + hit    # absolute step index
                else:
                    # wrapped scan continues from the end of the wrap-0
                    # segment: (n_steps - start0) steps past i0, + hit
                    j, idx = i0 + (self.n_steps - start0) + hit, hit
                return max(0.0, j * self.step_s - t0), float(col_r[idx])
        return self.horizon_s, 2_000_000.0

    def windows(self, sat: int, t0: float = 0.0,
                horizon_s: float | None = None) -> list[tuple[float, float]]:
        """Contact windows for ``sat`` opening in [t0, t0 + horizon_s):
        absolute (t_open, t_close) pairs, in order.

        Opens are grid-aligned (first visible sample at/after t0) except
        for a pass already in progress at an off-grid t0, which opens at
        t0 itself — the same ongoing-pass rule ``next_window`` applies.
        Closes are always the pass's TRUE close (first invisible sample,
        scanned past the query horizon if needed, up to one table
        period), never truncated at the horizon: the event kernel
        (repro.sim.windows) schedules CONTACT_CLOSE from these, and a
        truncated close would fabricate a loss of visibility. Absolute
        step indices wrap periodically through the table, so a pass
        straddling the table boundary reads as ONE window.
        """
        horizon = self.horizon_s if horizon_s is None else float(horizon_s)
        step, n = self.step_s, self.n_steps
        col = self.vis[:, sat]
        i0 = int(np.ceil(t0 / step))
        i_end = int(np.ceil((t0 + horizon) / step))
        i_floor = int(np.floor(t0 / step))
        out: list[tuple[float, float]] = []
        open_t: float | None = None
        j = i0
        while j < i_end:
            v = bool(col[j % n])
            if v and open_t is None:
                ongoing = (j == i0 and i_floor != i0
                           and bool(col[i_floor % n]))
                open_t = float(t0) if ongoing else j * step
            elif not v and open_t is not None:
                out.append((open_t, j * step))
                open_t = None
            j += 1
        if open_t is not None:
            # pass still open at the query horizon: find its true close
            for k in range(j, j + n):
                if not col[k % n]:
                    out.append((open_t, k * step))
                    break
            else:           # visible the whole period (not a LEO pass,
                out.append((open_t, (j + n) * step))   # but stay total)
        return out
