"""Constellation environment for the CroSatFL session controller.

Wires Walker-Delta geometry + LISL graph + GS visibility + hardware
profiles into the ``env`` duck-type used by ``core/session.Session`` and
the baselines (fl/baselines.py). Clients are a random subset of the 720
satellites (paper: 40 clients, 9 clusters).

Routing: at the paper's LISL ranges (659-1700 km) the in-plane neighbor
spacing is ~2170 km, so direct links are mostly to adjacent planes. Client
pairs therefore communicate over the constellation's full LISL mesh with
multi-hop routing (bounded by ``max_hops``); the effective path length is
the straight-line distance x a detour factor. Reachability is re-derived
from the instantaneous topology each time it is queried (time-varying
E_LISL(t) per paper §III-A), with per-satellite fan-out caps applied at
graph construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.constellation.gs import GroundStation
from repro.constellation.hardware import fanout_for_range, make_profiles
from repro.constellation.lisl import LISLConfig, earth_blocked, lisl_graph
from repro.constellation.walker import WalkerDelta
from repro.core.energy import HardwareProfile, LinkParams


class ConstellationEnv:
    def __init__(self,
                 n_clients: int = 40,
                 n_samples: Optional[np.ndarray] = None,
                 gpu_fraction: float = 0.5,
                 lisl_range_m: float = 1_500_000.0,
                 max_hops: int = 10,
                 detour: float = 1.2,
                 seed: int = 0,
                 constellation: Optional[WalkerDelta] = None,
                 link_params: Optional[LinkParams] = None):
        self.rng = np.random.default_rng(seed)
        self.constellation = constellation or WalkerDelta()
        self.gs = GroundStation()
        self.link_params = link_params or LinkParams()
        self.lisl_cfg = LISLConfig(range_m=lisl_range_m,
                                   fanout_default=fanout_for_range(lisl_range_m))
        self.n_clients = n_clients
        self.max_hops = max_hops
        self.detour = detour
        # spread clients across planes (paper selects 40 of 720 randomly)
        self.sat_ids = np.sort(self.rng.choice(
            self.constellation.n_sats, n_clients, replace=False))
        self.profiles: list[HardwareProfile] = make_profiles(
            n_clients, gpu_fraction, self.rng)
        self.n_samples = (n_samples if n_samples is not None
                          else self.rng.integers(200, 800, n_clients).astype(float))
        base_fo = self.lisl_cfg.fanout_default
        self.fanout = self.rng.integers(max(2, base_fo - 1), base_fo + 2,
                                        n_clients)
        self._topo_cache: dict[float, np.ndarray] = {}

    # ---- LISL ---------------------------------------------------------------
    def _client_positions(self, t: float) -> np.ndarray:
        return self.constellation.positions(t)[self.sat_ids]

    def _full_reach(self, t: float) -> np.ndarray:
        """(720, 720) bool: reachable within ``max_hops`` over the
        instantaneous fan-out-capped LISL mesh. Cached per time key."""
        key = round(t / 60.0)            # 1-minute topology granularity
        if key not in self._topo_cache:
            adj = lisl_graph(self.constellation, key * 60.0, self.lisl_cfg)
            reach = adj.copy()
            cur = adj.astype(np.uint8)
            a8 = adj.astype(np.uint8)
            for _ in range(self.max_hops - 1):
                cur = np.minimum(cur @ a8, 1)
                reach |= cur.astype(bool)
            np.fill_diagonal(reach, False)
            if len(self._topo_cache) > 64:
                self._topo_cache.clear()
            self._topo_cache[key] = reach
        return self._topo_cache[key]

    def lisl_distance(self, i: int, j: int, t: float) -> float:
        """Client-index pair -> effective routed path length in meters
        (straight-line x detour), inf when not reachable in max_hops."""
        if i == j:
            return 0.0
        si, sj = int(self.sat_ids[i]), int(self.sat_ids[j])
        if not self._full_reach(t)[si, sj]:
            return np.inf
        pos = self.constellation.positions(t)
        return float(np.linalg.norm(pos[si] - pos[sj])) * self.detour

    def client_adjacency(self, t: float) -> np.ndarray:
        """(n, n) client-level reachability (multi-hop routed)."""
        reach = self._full_reach(t)
        return reach[np.ix_(self.sat_ids, self.sat_ids)]

    def master_reach(self, masters: np.ndarray, t: float) -> np.ndarray:
        """(K, K) reachability among cluster masters over routed LISLs."""
        sats = self.sat_ids[masters]
        return self._full_reach(t)[np.ix_(sats, sats)]

    def next_master_contact(self, masters: np.ndarray, kc: int, t0: float,
                            max_wait_s: float = 1800.0,
                            step_s: float = 60.0) -> float:
        """Wait (s) from t0 until cluster ``kc``'s master can reach ANY
        other master over routed LISLs — the merge-commit gate of the
        event-driven async pacing (repro.sim.driver).

        Scans forward on the same 1-minute topology epochs that
        ``_full_reach`` caches on, so repeated queries within a round are
        cache hits. Capped at ``max_wait_s``: the mesh is dense enough
        that a master isolated for half an hour is a modeling bug, and
        the mixers already price relayed/deferred exchange, so the cap
        degrades to "merge now over the relay path" rather than hanging
        the simulation."""
        masters = np.asarray(masters, int)
        if masters.size <= 1:
            return 0.0
        t = float(t0)
        while t - t0 <= max_wait_s:
            row = self.master_reach(masters, t)[kc].copy()
            row[kc] = False
            if row.any():
                return t - t0
            t = (np.floor(t / step_s) + 1.0) * step_s
        return float(max_wait_s)

    def lisl_contact_windows(self, i: int, j: int, t0: float = 0.0,
                             horizon_s: float = 5_700.0,
                             step_s: float = 30.0,
                             ) -> list[tuple[float, float]]:
        """Direct-LISL visibility windows for client pair (i, j):
        absolute (t_open, t_close) pairs in [t0, t0 + horizon_s) where
        the pair is within LISL range and clear of the Earth's limb.

        Pairwise grid scan via ``WalkerDelta.subset_positions`` (two
        satellites, not 720) — an event source for inter-master transfer
        scheduling, complementing the GS ``WindowTable``."""
        si, sj = int(self.sat_ids[i]), int(self.sat_ids[j])
        ts = t0 + np.arange(0.0, horizon_s, step_s)
        pos = self.constellation.subset_positions([si, sj], ts)  # (T,2,3)
        pi, pj = pos[:, 0], pos[:, 1]
        dist = np.linalg.norm(pi - pj, axis=-1)
        ok = (dist < self.lisl_cfg.range_m) & ~earth_blocked(pi, pj)
        out: list[tuple[float, float]] = []
        open_t = None
        for k, v in enumerate(ok):
            if v and open_t is None:
                open_t = float(ts[k])
            elif not v and open_t is not None:
                out.append((open_t, float(ts[k])))
                open_t = None
        if open_t is not None:
            out.append((open_t, float(t0 + horizon_s)))
        return out

    # ---- GS -------------------------------------------------------------------
    @property
    def _windows(self):
        if not hasattr(self, "_window_table"):
            from repro.constellation.gs import WindowTable
            self._window_table = WindowTable(self.gs, self.constellation)
        return self._window_table

    @property
    def window_table(self):
        """Public handle on the precomputed GS-visibility table — the
        event kernel (repro.sim.windows) iterates its contact windows as
        an event source; built lazily on first access like the private
        ``next_window`` path."""
        return self._windows

    def gs_window_wait(self, client: int, t: float) -> tuple[float, float]:
        return self._windows.next_window(int(self.sat_ids[client]), t)

    def gs_visible_now(self, client: int, t: float) -> bool:
        pos = self.constellation.positions(t)[self.sat_ids[client]]
        return bool(self.gs.visible(pos, t))
