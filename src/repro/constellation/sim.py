"""Constellation environment for the CroSatFL session controller.

Wires Walker-Delta geometry + LISL graph + GS visibility + hardware
profiles into the ``env`` duck-type used by ``core/session.Session`` and
the baselines (fl/baselines.py). Clients are a random subset of the 720
satellites (paper: 40 clients, 9 clusters).

Routing: at the paper's LISL ranges (659-1700 km) the in-plane neighbor
spacing is ~2170 km, so direct links are mostly to adjacent planes. Client
pairs therefore communicate over the constellation's full LISL mesh with
multi-hop routing (bounded by ``max_hops``); the effective path length is
the straight-line distance x a detour factor. Reachability is re-derived
from the instantaneous topology each time it is queried (time-varying
E_LISL(t) per paper §III-A), with per-satellite fan-out caps applied at
graph construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.constellation.gs import GroundStation
from repro.constellation.hardware import fanout_for_range, make_profiles
from repro.constellation.lisl import LISLConfig, lisl_graph
from repro.constellation.walker import WalkerDelta
from repro.core.energy import HardwareProfile, LinkParams


class ConstellationEnv:
    def __init__(self,
                 n_clients: int = 40,
                 n_samples: Optional[np.ndarray] = None,
                 gpu_fraction: float = 0.5,
                 lisl_range_m: float = 1_500_000.0,
                 max_hops: int = 10,
                 detour: float = 1.2,
                 seed: int = 0,
                 constellation: Optional[WalkerDelta] = None,
                 link_params: Optional[LinkParams] = None):
        self.rng = np.random.default_rng(seed)
        self.constellation = constellation or WalkerDelta()
        self.gs = GroundStation()
        self.link_params = link_params or LinkParams()
        self.lisl_cfg = LISLConfig(range_m=lisl_range_m,
                                   fanout_default=fanout_for_range(lisl_range_m))
        self.n_clients = n_clients
        self.max_hops = max_hops
        self.detour = detour
        # spread clients across planes (paper selects 40 of 720 randomly)
        self.sat_ids = np.sort(self.rng.choice(
            self.constellation.n_sats, n_clients, replace=False))
        self.profiles: list[HardwareProfile] = make_profiles(
            n_clients, gpu_fraction, self.rng)
        self.n_samples = (n_samples if n_samples is not None
                          else self.rng.integers(200, 800, n_clients).astype(float))
        base_fo = self.lisl_cfg.fanout_default
        self.fanout = self.rng.integers(max(2, base_fo - 1), base_fo + 2,
                                        n_clients)
        self._topo_cache: dict[float, np.ndarray] = {}

    # ---- LISL ---------------------------------------------------------------
    def _client_positions(self, t: float) -> np.ndarray:
        return self.constellation.positions(t)[self.sat_ids]

    def _full_reach(self, t: float) -> np.ndarray:
        """(720, 720) bool: reachable within ``max_hops`` over the
        instantaneous fan-out-capped LISL mesh. Cached per time key."""
        key = round(t / 60.0)            # 1-minute topology granularity
        if key not in self._topo_cache:
            adj = lisl_graph(self.constellation, key * 60.0, self.lisl_cfg)
            reach = adj.copy()
            cur = adj.astype(np.uint8)
            a8 = adj.astype(np.uint8)
            for _ in range(self.max_hops - 1):
                cur = np.minimum(cur @ a8, 1)
                reach |= cur.astype(bool)
            np.fill_diagonal(reach, False)
            if len(self._topo_cache) > 64:
                self._topo_cache.clear()
            self._topo_cache[key] = reach
        return self._topo_cache[key]

    def lisl_distance(self, i: int, j: int, t: float) -> float:
        """Client-index pair -> effective routed path length in meters
        (straight-line x detour), inf when not reachable in max_hops."""
        if i == j:
            return 0.0
        si, sj = int(self.sat_ids[i]), int(self.sat_ids[j])
        if not self._full_reach(t)[si, sj]:
            return np.inf
        pos = self.constellation.positions(t)
        return float(np.linalg.norm(pos[si] - pos[sj])) * self.detour

    def client_adjacency(self, t: float) -> np.ndarray:
        """(n, n) client-level reachability (multi-hop routed)."""
        reach = self._full_reach(t)
        return reach[np.ix_(self.sat_ids, self.sat_ids)]

    def master_reach(self, masters: np.ndarray, t: float) -> np.ndarray:
        """(K, K) reachability among cluster masters over routed LISLs."""
        sats = self.sat_ids[masters]
        return self._full_reach(t)[np.ix_(sats, sats)]

    # ---- GS -------------------------------------------------------------------
    @property
    def _windows(self):
        if not hasattr(self, "_window_table"):
            from repro.constellation.gs import WindowTable
            self._window_table = WindowTable(self.gs, self.constellation)
        return self._window_table

    def gs_window_wait(self, client: int, t: float) -> tuple[float, float]:
        return self._windows.next_window(int(self.sat_ids[client]), t)

    def gs_visible_now(self, client: int, t: float) -> bool:
        pos = self.constellation.positions(t)[self.sat_ids[client]]
        return bool(self.gs.visible(pos, t))
