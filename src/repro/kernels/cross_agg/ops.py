"""Jitted public wrapper: apply the fused mixing kernel to a stacked
(K, ...) model pytree (the datacenter path of core/crossagg.apply_mixing).

Leaves are flattened and concatenated into one (K, N_total) buffer so the
kernel makes a single pass over HBM regardless of how fragmented the
parameter tree is, then split back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cross_agg.kernel import cross_agg_flat


def cross_agg_tree(M: jax.Array, stacked, *, interpret: bool = True):
    """stacked: pytree with leading cluster dim K on every leaf."""
    leaves, treedef = jax.tree.flatten(stacked)
    K = leaves[0].shape[0]
    if K == 0:          # zero-participant round: nothing to mix
        return stacked
    dtype = leaves[0].dtype
    sizes = [int(np.prod(l.shape[1:])) for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(K, -1).astype(dtype) for l in leaves], axis=1)
    mixed = cross_agg_flat(M, flat, interpret=interpret)
    outs, off = [], 0
    for l, s in zip(leaves, sizes):
        outs.append(mixed[:, off:off + s].reshape(l.shape).astype(l.dtype))
        off += s
    return jax.tree.unflatten(treedef, outs)
