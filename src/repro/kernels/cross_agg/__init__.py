from repro.kernels.cross_agg.kernel import cross_agg_flat  # noqa: F401
from repro.kernels.cross_agg.ops import cross_agg_tree  # noqa: F401
from repro.kernels.cross_agg.ref import (cross_agg_flat_ref,  # noqa: F401
                                         cross_agg_tree_ref)
