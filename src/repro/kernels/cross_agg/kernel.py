"""Pallas TPU kernel for fused cross-aggregation (paper Eq. 37/38).

Computes ``out = M @ W`` where W is the (K, N) stack of K flattened cluster
models and M the (K, K) row-stochastic mixing matrix. The op is strongly
memory-bound (arithmetic intensity ~K FLOPs/byte with tiny K), so the win
over a naive per-pair implementation is HBM traffic: every W tile is read
ONCE from HBM into VMEM and all K output rows are produced in-register,
instead of K separate axpy passes re-reading the stack.

TPU adaptation (DESIGN.md §2/§5): tiles are (K_pad, TILE_N) with TILE_N a
multiple of 128 (lane dim) and K padded to the 8-row sublane granularity;
the (K_pad x K_pad) @ (K_pad x TILE_N) contraction maps onto the MXU.
VMEM claim per grid step = (K_pad*TILE_N in + K_pad^2 + K_pad*TILE_N out)
* 4 B; with K_pad = 16, TILE_N = 2048 that is ~0.26 MB — far under the
~16 MB VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 2048
SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _cross_agg_kernel(m_ref, w_ref, o_ref):
    # m_ref: (K_pad, K_pad); w_ref: (K_pad, TILE_N); o_ref: (K_pad, TILE_N)
    o_ref[...] = jnp.dot(m_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def cross_agg_flat(M: jax.Array, W: jax.Array, *, tile_n: int = TILE_N,
                   interpret: bool = True) -> jax.Array:
    """M: (K, K) f32; W: (K, N) any float dtype. Returns (K, N) of W.dtype."""
    K, N = W.shape
    K_pad = _round_up(max(K, 1), SUBLANE)
    N_pad = _round_up(max(N, 1), tile_n)

    Mp = jnp.zeros((K_pad, K_pad), jnp.float32).at[:K, :K].set(
        M.astype(jnp.float32))
    Wp = jnp.zeros((K_pad, N_pad), W.dtype).at[:K, :N].set(W)

    out = pl.pallas_call(
        _cross_agg_kernel,
        grid=(N_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((K_pad, K_pad), lambda i: (0, 0)),
            pl.BlockSpec((K_pad, tile_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((K_pad, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((K_pad, N_pad), W.dtype),
        interpret=interpret,
    )(Mp, Wp)
    return out[:K, :N]
