"""Pure-jnp oracle for the cross-aggregation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_agg_flat_ref(M: jax.Array, W: jax.Array) -> jax.Array:
    """out[k] = sum_j M[k, j] * W[j] in f32, cast back to W.dtype."""
    return (M.astype(jnp.float32) @ W.astype(jnp.float32)).astype(W.dtype)


def cross_agg_tree_ref(M: jax.Array, stacked):
    def mix(leaf):
        K = leaf.shape[0]
        return cross_agg_flat_ref(M, leaf.reshape(K, -1)).reshape(leaf.shape)
    return jax.tree.map(mix, stacked)
