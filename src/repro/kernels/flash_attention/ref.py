"""Pure-jnp oracle for flash attention (naive full-matrix softmax)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d). fp32 math."""
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
