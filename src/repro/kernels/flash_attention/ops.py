"""Public op: (B, S, H, d)-layout wrapper used by the model stack.

On TPU targets this is the drop-in replacement for
``models.layers.chunked_attention`` on full-causal archs; on this CPU
container the model stack keeps the jnp path and the kernel is validated
in interpret mode (tests/test_kernels.py sweeps shapes and dtypes).
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention


def attention_bshd(q, k, v, *, causal: bool = True, interpret: bool = True):
    """q: (B, Sq, H, d); k, v: (B, Sk, Hkv, d) — model-stack layout."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention(qt, kt, vt, causal=causal, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
