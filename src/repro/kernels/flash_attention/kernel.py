"""Pallas TPU flash attention (blocked causal GQA, online softmax).

TPU adaptation of the CUDA flash-attention idea (DESIGN.md §2): instead of
warp-level softmax reductions, the kernel tiles (block_q x block_k) score
panels through VMEM with fp32 running (m, l, acc) scratch carried across
the sequential k-block grid dimension, and feeds the MXU with
(block_q x d) @ (d x block_k) panels. Block sizes are multiples of the
128-lane / 8-sublane tile and chosen so the per-step working set

    q(bq*d) + k(bk*d) + v(bk*d) + acc(bq*d) + scores(bq*bk)   (fp32)

stays a few MB under the ~16 MB VMEM budget (bq = bk = 512, d = 128 ->
~1.8 MB). Causality skips whole (i, j) panels above the diagonal — the
triangular schedule halves the visited panels; the diagonal panel applies
the elementwise mask.

GQA: grid dim 0 enumerates (batch x q-heads); the k/v index map folds the
q-head onto its kv head (h // group). The kernel never materializes the
(Sq, Sk) matrix.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG = -1e30

# Newer Pallas names this CompilerParams; jax<=0.4.x only has
# TPUCompilerParams (on transitional versions it is a deprecated alias, so
# prefer the new name when both exist).
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, block_q: int, block_k: int, causal: bool,
                  nk: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # k block (sequential)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (j <= i) if causal else True

    @pl.when(run if causal else (j >= 0))
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    last = jnp.minimum(i, nk - 1) if causal else nk - 1

    @pl.when(j == last)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = DEFAULT_BQ,
                    block_k: int = DEFAULT_BK,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d); Hq % Hkv == 0.

    Returns (B, Hq, Sq, d) in q.dtype.
    """
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(d)

    qf = q.reshape(B * Hq, Sq, d)
    kf = k.reshape(B * Hkv, Sk, d)
    vf = v.reshape(B * Hkv, Sk, d)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=bq,
                               block_k=bk, causal=causal, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, d)
