from repro.kernels.quant.kernel import int8_dequantize, int8_quantize  # noqa: F401
from repro.kernels.quant.ops import (compress_tree, compressed_bytes,  # noqa: F401
                                     decompress_tree)
from repro.kernels.quant.ref import (int8_dequantize_ref,  # noqa: F401
                                     int8_quantize_ref)
