"""Public pytree-level compressed-payload ops over the quant kernel."""
from __future__ import annotations

import math

import jax

from repro.kernels.quant.kernel import int8_dequantize, int8_quantize


def compress_tree(tree, *, interpret: bool = True):
    def comp(x):
        q, s = int8_quantize(x, interpret=interpret)
        return {"q": q, "scale": s, "shape": tuple(x.shape),
                "n": int(x.size), "dtype": x.dtype}
    return jax.tree.map(comp, tree)


def decompress_tree(ctree, *, interpret: bool = True):
    def dec(c):
        return int8_dequantize(c["q"], c["scale"], n=c["n"],
                               shape=c["shape"], dtype=c["dtype"],
                               interpret=interpret)
    return jax.tree.map(dec, ctree,
                        is_leaf=lambda t: isinstance(t, dict) and "q" in t)


def compressed_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        from repro.kernels.quant.kernel import CHUNK
        n = leaf.size
        total += n + 4 * math.ceil(n / CHUNK)
    return total
