"""Pallas TPU kernel: fused symmetric int8 quantize / dequantize.

Used for compressed LISL payloads (FedOrbit-style reduced precision and
the beyond-paper compressed cross-aggregation hop). Per-chunk scales:

    scale_c = max|x_c| / 127 ;  q_c = round(x_c / scale_c)

The fusion point: absmax-reduce, scale division, round and cast all happen
in one VMEM pass — the naive jnp version reads x twice (reduce, then
quantize). Tiles are (rows, 128-multiple) blocks; the absmax reduction
runs on the VPU along lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 1024
ROWS = 8      # sublane granularity: each grid step quantizes ROWS chunks


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                 # (ROWS, CHUNK)
    absmax = jnp.abs(x).max(axis=1)                    # (ROWS,)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) *
                  s_ref[...][:, None]).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def int8_quantize(x: jax.Array, *, chunk: int = CHUNK,
                  interpret: bool = True):
    """x: any shape. Returns (q (n_chunks, chunk) int8, scale (n_chunks,) f32,
    meta dict). n padded to ROWS*chunk granularity."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_step = ROWS * chunk
    n_pad = (n + per_step - 1) // per_step * per_step
    flat = jnp.pad(flat, (0, n_pad - n))
    blocks = flat.reshape(-1, chunk)                   # (n_chunks, chunk)
    n_chunks = blocks.shape[0]

    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(n_chunks // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, chunk), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, chunk), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n_chunks, chunk), jnp.int8),
                   jax.ShapeDtypeStruct((n_chunks,), jnp.float32)],
        interpret=interpret,
    )(blocks)
    return q, s


@functools.partial(jax.jit, static_argnames=("n", "shape", "dtype",
                                             "interpret"))
def int8_dequantize(q: jax.Array, s: jax.Array, *, n: int, shape, dtype,
                    interpret: bool = True):
    n_chunks, chunk = q.shape
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(n_chunks // ROWS,),
        in_specs=[pl.BlockSpec((ROWS, chunk), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS,), lambda i: (i,))],
        out_specs=pl.BlockSpec((ROWS, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, chunk), dtype),
        interpret=interpret,
    )(q, s)
    return x.reshape(-1)[:n].reshape(shape)
