"""Pure-jnp oracle for the int8 quantization kernel (matches
optim/compression.py semantics with ROWS-granular padding)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.quant.kernel import CHUNK, ROWS


def int8_quantize_ref(x, *, chunk: int = CHUNK):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    per_step = ROWS * chunk
    n_pad = (n + per_step - 1) // per_step * per_step
    flat = jnp.pad(flat, (0, n_pad - n))
    blocks = flat.reshape(-1, chunk)
    scale = jnp.maximum(jnp.abs(blocks).max(1) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize_ref(q, s, *, n: int, shape, dtype):
    flat = (q.astype(jnp.float32) * s[:, None]).reshape(-1)
    return flat[:n].reshape(shape).astype(dtype)
