"""repro.faults — deterministic fault injection + chaos harness
(DESIGN.md §13).

* ``model``  — typed faults (link outages, crashes/reboots, master
  failure, payload corruption/loss, clock drift), the seeded
  ``FaultSchedule`` (explicit / Poisson / Gilbert-Elliott), the
  ``FaultState`` live view, and the ``FaultInjector`` the engine polls.
* ``chaos``  — ``python -m repro.faults.chaos``: seeded fault campaigns
  across scenario presets asserting no-deadlock, bit-exact mirror
  reconcile, and recovery invariants.

Recovery policies live with the behavior they guard: transport retries
in ``fl/engine/transport.py``, master failover + skip-many in
``fl/engine/engine.py``, checkpoint fallback in ``ckpt/store.py``.
"""
from repro.faults.model import (GS, LISL, SILENT_MODES, ClockDrift,
                                FaultInjector, FaultSchedule, FaultState,
                                LinkOutage, MasterFailure,
                                PayloadCorruption, PayloadLoss, SatCrash,
                                SatReboot, SilentCorruption, as_injector,
                                corruption_schedule, smoke_schedule)

__all__ = [
    "GS", "LISL", "SILENT_MODES", "ClockDrift", "FaultInjector",
    "FaultSchedule", "FaultState", "LinkOutage", "MasterFailure",
    "PayloadCorruption", "PayloadLoss", "SatCrash", "SatReboot",
    "SilentCorruption", "as_injector", "corruption_schedule",
    "smoke_schedule",
]
