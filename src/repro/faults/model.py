"""Deterministic fault model for the constellation (DESIGN.md §13).

Three layers, all seeded and bit-reproducible:

* **Typed faults** — frozen dataclasses describing one adverse
  occurrence on the sim clock: a ``LinkOutage`` (LISL or GS class, with
  a duration), a ``SatCrash``/``SatReboot`` pair, a ``MasterFailure``
  (the master *role* dies; the satellite survives as a member), a
  ``PayloadCorruption``/``PayloadLoss`` (the next message is garbage /
  never arrives and must be retransmitted), and a ``ClockDrift`` (a
  cluster's clock slews and the skew is burned as wait time).
* **FaultSchedule** — an explicit fault list, or one materialized from a
  seeded Poisson process (independent arrival streams per fault family)
  or a Gilbert-Elliott two-state burst chain over a link class. All
  randomness comes from a private ``np.random.default_rng(seed)``
  consumed eagerly at construction, so a schedule is a pure value:
  equal seeds give equal schedules, replays are trivially deterministic.
* **FaultInjector** — owns a private ``repro.sim.events.EventQueue``
  loaded with the schedule (faults enter the kernel's extended
  kind-priority total order; the kernel's RNG is its own, so attaching
  an injector cannot perturb the engine's host RNG or JAX key stream —
  the same discipline as the event drivers) and a ``FaultState`` live
  view the recovery policies read: which links are out until when, which
  satellites are down, which payload faults are pending. The engine
  polls the injector at round boundaries; ``Transport`` consults the
  live view mid-accounting.

Recovery policies live where the behavior they guard lives: retries in
``fl/engine/transport.py``, failover + skip-many in
``fl/engine/engine.py``, checkpoint fallback in ``ckpt/store.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.sim.events import (CLOCK_DRIFT, LINK_DOWN, LINK_UP, MASTER_FAIL,
                              PAYLOAD_CORRUPT, PAYLOAD_LOSS, SAT_CRASH,
                              SAT_REBOOT, SILENT_CORRUPT, EventQueue)

LISL, GS = "lisl", "gs"   # link classes (Transport: intra/inter -> lisl)


# ---------------------------------------------------------------------------
# Typed faults
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LinkOutage:
    """Link class ``link`` is unusable for ``duration_s`` from ``t``.
    ``cluster=None`` hits every cluster's links of that class."""
    t: float
    duration_s: float
    link: str = LISL
    cluster: Optional[int] = None


@dataclass(frozen=True)
class SatCrash:
    """Client ``sat`` goes dark at ``t`` and reboots after
    ``duration_s`` (the injector schedules the paired SAT_REBOOT)."""
    t: float
    sat: int
    duration_s: float = 600.0


@dataclass(frozen=True)
class SatReboot:
    """Explicit early revive of a crashed client."""
    t: float
    sat: int


@dataclass(frozen=True)
class MasterFailure:
    """Cluster ``cluster``'s master ROLE fails at ``t`` (aggregation
    process dies); the satellite itself stays up as a member and the
    engine re-elects."""
    t: float
    cluster: int


@dataclass(frozen=True)
class PayloadCorruption:
    """The next message batch (from ``cluster``, or anywhere when None)
    arrives corrupted: the receiver discards it and the full batch is
    retransmitted at real energy cost."""
    t: float
    cluster: Optional[int] = None


@dataclass(frozen=True)
class PayloadLoss:
    """Like PayloadCorruption but the batch never arrives (same recovery
    cost, distinct trace/metrics label)."""
    t: float
    cluster: Optional[int] = None


SILENT_MODES = ("sign_flip", "large_scale", "nan_splat", "bit_noise")


@dataclass(frozen=True)
class SilentCorruption:
    """A delivered update from ``cluster`` (seeded pick when None) is
    perturbed PAST the transport checksum — the link saw a valid
    payload, but the values are poison (radiation bit flips, stuck
    compute, adversarial member). The injector stashes the descriptor;
    the engine applies it to the fresh cluster model between training
    and the pacing merge, so it reaches the aggregation layer exactly
    like a real silent fault would. ``mode``:

    * ``sign_flip``   — every weight negated
    * ``large_scale`` — weights multiplied by ``scale``
    * ``nan_splat``   — the whole lane becomes NaN
    * ``bit_noise``   — a seeded ~1% of float32 elements get one random
      mantissa/exponent/sign bit XOR'd (the literal radiation model)

    The corruption is a pure function of the descriptor (per-leaf keys
    fold the leaf index into ``PRNGKey(seed)``), so list and stacked
    execution paths — and checkpoint resumes — corrupt identically."""
    t: float
    cluster: Optional[int] = None
    mode: str = "sign_flip"
    scale: float = 100.0
    seed: int = 0

    def __post_init__(self):
        if self.mode not in SILENT_MODES:
            raise ValueError(f"mode must be one of {SILENT_MODES}, "
                             f"got {self.mode!r}")


@dataclass(frozen=True)
class ClockDrift:
    """Cluster ``cluster``'s local clock slews by ``skew_s``; the
    re-synchronization is charged as latency-only wait time."""
    t: float
    cluster: int
    skew_s: float = 5.0


_KIND = {LinkOutage: LINK_DOWN, SatCrash: SAT_CRASH, SatReboot: SAT_REBOOT,
         MasterFailure: MASTER_FAIL, PayloadCorruption: PAYLOAD_CORRUPT,
         PayloadLoss: PAYLOAD_LOSS, ClockDrift: CLOCK_DRIFT,
         SilentCorruption: SILENT_CORRUPT}


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultSchedule:
    """An immutable, time-sorted campaign of typed faults plus the
    recovery knobs the policies read (retry cap, backoff base).

    Construct explicitly (``FaultSchedule([...])``) or from the seeded
    generators below. An EMPTY schedule attached to an engine is
    contractually a no-op: the ledger stays bit-identical to an
    unattached run (pinned in tests/test_faults.py).
    """
    faults: tuple = ()
    seed: int = 0
    max_retries: int = 4
    backoff0_s: float = 30.0

    def __post_init__(self):
        order = sorted(self.faults,
                       key=lambda f: (f.t, _KIND[type(f)], repr(f)))
        object.__setattr__(self, "faults", tuple(order))

    def __len__(self) -> int:
        return len(self.faults)

    # -- seeded generators ---------------------------------------------------
    @classmethod
    def poisson(cls, horizon_s: float, seed: int = 0, *,
                n_clusters: int = 0, n_clients: int = 0,
                outage_rate_per_h: float = 0.0, mean_outage_s: float = 120.0,
                gs_outage_frac: float = 0.25,
                crash_rate_per_h: float = 0.0, mean_down_s: float = 600.0,
                master_fail_rate_per_h: float = 0.0,
                payload_rate_per_h: float = 0.0,
                drift_rate_per_h: float = 0.0, mean_skew_s: float = 5.0,
                silent_rate_per_h: float = 0.0,
                silent_scale: float = 100.0,
                max_retries: int = 4,
                backoff0_s: float = 30.0) -> "FaultSchedule":
        """Independent Poisson arrival streams per fault family over
        ``[0, horizon_s)``; exponential durations; uniform targets. One
        private generator, consumed in a fixed family order — the whole
        campaign is a pure function of the arguments. The silent family
        draws AFTER every PR-9 family (and draws nothing at rate 0), so
        pre-existing schedules stay bit-identical."""
        rng = np.random.default_rng(seed)
        faults: list = []

        def arrivals(rate_per_h: float) -> list:
            out, t = [], 0.0
            if rate_per_h <= 0:
                return out
            while True:
                t += float(rng.exponential(3600.0 / rate_per_h))
                if t >= horizon_s:
                    return out
                out.append(t)

        for t in arrivals(outage_rate_per_h):
            link = GS if rng.random() < gs_outage_frac else LISL
            kc = (None if n_clusters == 0 or rng.random() < 0.5
                  else int(rng.integers(n_clusters)))
            faults.append(LinkOutage(t, float(rng.exponential(mean_outage_s)),
                                     link, kc))
        for t in arrivals(crash_rate_per_h):
            faults.append(SatCrash(t, int(rng.integers(max(n_clients, 1))),
                                   float(rng.exponential(mean_down_s))))
        for t in arrivals(master_fail_rate_per_h):
            faults.append(MasterFailure(
                t, int(rng.integers(max(n_clusters, 1)))))
        for t in arrivals(payload_rate_per_h):
            kc = (None if n_clusters == 0 or rng.random() < 0.5
                  else int(rng.integers(n_clusters)))
            cls_ = PayloadLoss if rng.random() < 0.5 else PayloadCorruption
            faults.append(cls_(t, kc))
        for t in arrivals(drift_rate_per_h):
            faults.append(ClockDrift(
                t, int(rng.integers(max(n_clusters, 1))),
                float(rng.exponential(mean_skew_s))))
        for t in arrivals(silent_rate_per_h):
            kc = (None if n_clusters == 0 or rng.random() < 0.5
                  else int(rng.integers(n_clusters)))
            mode = SILENT_MODES[int(rng.integers(len(SILENT_MODES)))]
            faults.append(SilentCorruption(
                t, kc, mode, scale=silent_scale,
                seed=int(rng.integers(2 ** 31 - 1))))
        return cls(tuple(faults), seed=seed, max_retries=max_retries,
                   backoff0_s=backoff0_s)

    @classmethod
    def gilbert_elliott(cls, horizon_s: float, seed: int = 0, *,
                        link: str = LISL, cluster: Optional[int] = None,
                        p_g2b: float = 0.02, p_b2g: float = 0.5,
                        step_s: float = 60.0, mode: str = "outage",
                        corrupt_mode: str = "sign_flip",
                        max_retries: int = 4,
                        backoff0_s: float = 30.0) -> "FaultSchedule":
        """Two-state (Good/Bad) Markov burst chain sampled on a
        ``step_s`` grid. ``mode="outage"`` (default, byte-identical to
        the PR-9 generator): each maximal Bad run becomes one
        LinkOutage — the classic bursty-loss channel at link
        granularity. ``mode="silent"``: every Bad step instead emits one
        seeded ``SilentCorruption`` of ``corrupt_mode`` — the bursty
        radiation-environment channel (South Atlantic Anomaly passes)
        the checksum cannot see."""
        if mode not in ("outage", "silent"):
            raise ValueError(f"mode must be 'outage' or 'silent', "
                             f"got {mode!r}")
        rng = np.random.default_rng(seed)
        faults: list = []
        bad, run_start = False, 0.0
        t = 0.0
        while t < horizon_s:
            if bad:
                if rng.random() < p_b2g:
                    if mode == "outage":
                        faults.append(LinkOutage(run_start, t - run_start,
                                                 link, cluster))
                    bad = False
            else:
                if rng.random() < p_g2b:
                    bad, run_start = True, t
            if bad and mode == "silent":
                faults.append(SilentCorruption(
                    t, cluster, corrupt_mode,
                    seed=int(rng.integers(2 ** 31 - 1))))
            t += step_s
        if bad and mode == "outage":
            faults.append(LinkOutage(run_start, horizon_s - run_start,
                                     link, cluster))
        return cls(tuple(faults), seed=seed, max_retries=max_retries,
                   backoff0_s=backoff0_s)


def smoke_schedule(seed: int = 0, *, n_clusters: int = 4,
                   n_clients: int = 8, crash_sat: int = 1,
                   horizon_s: float = 4000.0) -> FaultSchedule:
    """The chaos-smoke campaign (faults/chaos.py, benchmarks, CI): a
    deterministic MasterFailure + LISL outage + long SatCrash + payload
    corruption landing at t=0 — so the very first round demonstrably
    exercises failover, charged retries, skip-many, and retransmission —
    plus a seeded Poisson tail over the session horizon."""
    explicit = (
        MasterFailure(0.0, 0),
        LinkOutage(0.0, 200.0, LISL, None),
        SatCrash(0.0, crash_sat, 1e9),       # down for the whole session
        PayloadCorruption(0.0, None),
    )
    tail = FaultSchedule.poisson(
        horizon_s, seed=seed, n_clusters=n_clusters, n_clients=n_clients,
        outage_rate_per_h=2.0, mean_outage_s=90.0,
        crash_rate_per_h=0.5, mean_down_s=300.0,
        master_fail_rate_per_h=0.5, payload_rate_per_h=1.0,
        drift_rate_per_h=1.0)
    return FaultSchedule(explicit + tail.faults, seed=seed)


def corruption_schedule(seed: int = 0, *, n_clusters: int = 4,
                        n_clients: int = 8, crash_sat: int = 1,
                        horizon_s: float = 4000.0) -> FaultSchedule:
    """The silent-corruption campaign (faults/chaos.py, CI): a session-long
    SatCrash (so one cluster sits below quorum every round — the
    degraded-mode path demonstrably fires) plus NaN-splat silent
    corruption on clusters 0 AND 1 at t=0 (two poisoned lanes: even if
    the crashed satellite's quorum-gated cluster absorbs one, the other
    reaches the merge — plain FedAvg provably degrades) and a seeded
    Poisson tail of mixed-mode silent faults."""
    explicit = (
        SatCrash(0.0, crash_sat, 1e9),
        SilentCorruption(0.0, 0, "nan_splat", seed=seed),
        SilentCorruption(0.0, 1, "nan_splat", seed=seed + 1),
    )
    tail = FaultSchedule.poisson(
        horizon_s, seed=seed, n_clusters=n_clusters, n_clients=n_clients,
        silent_rate_per_h=3.0)
    return FaultSchedule(explicit + tail.faults, seed=seed)


# ---------------------------------------------------------------------------
# Live view
# ---------------------------------------------------------------------------

class FaultState:
    """What is broken RIGHT NOW — the view ``Transport`` and the engine
    read. Mutated only by ``FaultInjector._apply``; JSON round-trips for
    checkpointing (str-keyed, list-valued). The injector keeps ONE
    instance for its lifetime (reset/load mutate in place) so Transport
    views built before ``bind`` never go stale."""

    def __init__(self, max_retries: int = 4, backoff0_s: float = 30.0):
        self.max_retries = int(max_retries)
        self.backoff0_s = float(backoff0_s)
        # (link class, cluster|None) -> outage end time
        self.outage_until: dict = {}
        # client id -> reboot time (down while reboot_t > now)
        self.crashed: dict = {}
        # pending one-shot payload faults: (kind, cluster|None) -> count
        self.payload_pending: dict = {}
        self.dropped = 0              # degraded-mode drops (capped retries)
        # pending silent corruptions: descriptor dicts the engine
        # consumes between training and the merge (DESIGN.md §14)
        self.silent_pending: list = []

    def reset(self) -> None:
        self.outage_until.clear()
        self.crashed.clear()
        self.payload_pending.clear()
        self.dropped = 0
        self.silent_pending.clear()

    # -- queries (Transport / engine) ----------------------------------------
    def outage_end(self, link: str, kc: Optional[int], t: float) -> float:
        """Latest applicable outage end > t for this link class as seen
        from cluster ``kc`` (cluster-scoped and global outages both
        apply); 0.0 when the link is up."""
        end = 0.0
        for key in ((link, None if kc is None else int(kc)), (link, None)):
            e = self.outage_until.get(key, 0.0)
            if e > t and e > end:
                end = e
        return end

    def down(self, sat: int, t: float) -> bool:
        return self.crashed.get(int(sat), 0.0) > t

    def down_sats(self, t: float) -> list:
        return sorted(s for s, e in self.crashed.items() if e > t)

    def take_payload_fault(self, kc: Optional[int]) -> Optional[str]:
        """Consume one pending payload fault applicable to a message
        from cluster ``kc`` (cluster-scoped first, then global).
        Returns the fault kind consumed, or None."""
        for kind in (PAYLOAD_CORRUPT, PAYLOAD_LOSS):
            for key in ((kind, None if kc is None else int(kc)),
                        (kind, None)):
                n = self.payload_pending.get(key, 0)
                if n > 0:
                    self.payload_pending[key] = n - 1
                    return kind
        return None

    # -- checkpointing -------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "max_retries": self.max_retries,
            "backoff0_s": self.backoff0_s,
            "outage": [[link, kc, end] for (link, kc), end
                       in sorted(self.outage_until.items(),
                                 key=lambda kv: (kv[0][0],
                                                 -1 if kv[0][1] is None
                                                 else kv[0][1]))],
            "crashed": sorted([int(s), float(e)]
                              for s, e in self.crashed.items()),
            "payload": [[kind, kc, n] for (kind, kc), n
                        in sorted(self.payload_pending.items(),
                                  key=lambda kv: (kv[0][0],
                                                  -1 if kv[0][1] is None
                                                  else kv[0][1])) if n > 0],
            "dropped": int(self.dropped),
            "silent": [dict(d) for d in self.silent_pending],
        }

    def load(self, d: dict) -> None:
        """Restore ``to_dict()`` in place (JSON-round-tripped dicts ok)."""
        self.reset()
        self.max_retries = int(d.get("max_retries", self.max_retries))
        self.backoff0_s = float(d.get("backoff0_s", self.backoff0_s))
        self.outage_until.update(
            {(link, None if kc is None else int(kc)): float(e)
             for link, kc, e in d.get("outage", [])})
        self.crashed.update({int(s): float(e)
                             for s, e in d.get("crashed", [])})
        self.payload_pending.update(
            {(kind, None if kc is None else int(kc)): int(n)
             for kind, kc, n in d.get("payload", [])})
        self.dropped = int(d.get("dropped", 0))
        # absent on pre-silent-corruption checkpoints: default empty
        self.silent_pending.extend(dict(x) for x in d.get("silent", []))

    @classmethod
    def from_dict(cls, d: dict) -> "FaultState":
        fs = cls()
        fs.load(d)
        return fs


_BIT_NOISE_FRAC = 0.01   # seeded fraction of elements hit by bit_noise


def _corrupt_tree(tree, d: dict):
    """Apply one silent-corruption descriptor to a single model pytree.

    A pure function of (tree, descriptor): per-leaf keys fold the leaf
    index into ``PRNGKey(seed)``, so corrupting lane k of a stacked
    result and corrupting element k of a list result produce identical
    values — list/stacked executor parity is preserved under faults.
    Non-floating leaves pass through untouched.
    """
    import jax
    import jax.numpy as jnp

    mode = d["mode"]
    scale = float(d.get("scale", 100.0))
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        if not jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            out.append(leaf)
            continue
        leaf = jnp.asarray(leaf)
        if mode == "sign_flip":
            out.append(-leaf)
        elif mode == "large_scale":
            out.append((leaf * scale).astype(leaf.dtype))
        elif mode == "nan_splat":
            out.append(jnp.full_like(leaf, jnp.nan))
        elif mode == "bit_noise":
            key = jax.random.fold_in(
                jax.random.PRNGKey(int(d.get("seed", 0))), i)
            k_hit, k_bit = jax.random.split(key)
            bits = jax.lax.bitcast_convert_type(
                leaf.astype(jnp.float32), jnp.uint32)
            hit = jax.random.bernoulli(k_hit, _BIT_NOISE_FRAC, leaf.shape)
            pos = jax.random.randint(k_bit, leaf.shape, 0, 32)
            flip = jnp.where(hit,
                             jnp.left_shift(jnp.uint32(1),
                                            pos.astype(jnp.uint32)),
                             jnp.uint32(0))
            out.append(jax.lax.bitcast_convert_type(
                bits ^ flip, jnp.float32).astype(leaf.dtype))
        else:                        # pragma: no cover - descriptor checked
            raise ValueError(f"unknown silent-corruption mode {mode!r}")
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Injector
# ---------------------------------------------------------------------------

class FaultInjector:
    """Feeds a ``FaultSchedule`` through a private event kernel into the
    running engine.

    The engine calls ``bind`` once per ``run()`` (fresh sessions load
    the schedule into the kernel; resumed sessions arrive with the
    kernel already restored from ``SessionState.faults_state``, pending
    future events included), ``poll`` at every round boundary (applies
    every due fault to the live ``FaultState``, emits ``obs.fault``, and
    performs master failover), and ``apply_selection`` after each
    cluster's selection (skip-many: crashed members are forced out of
    the participant mask with Skip-One fairness carryover).
    """

    def __init__(self, schedule: FaultSchedule, seed: Optional[int] = None):
        self.schedule = schedule
        self.kernel = EventQueue(schedule.seed if seed is None else seed)
        self.state = FaultState(schedule.max_retries, schedule.backoff0_s)

    # -- engine lifecycle ----------------------------------------------------
    def bind(self, ctx, plan, state) -> None:
        if state.round_idx == 0:
            self.kernel.reset()
            self.state.reset()
            for f in self.schedule.faults:
                self._push(f)

    def _push(self, f) -> None:
        kind = _KIND[type(f)]
        if isinstance(f, LinkOutage):
            self.kernel.push(f.t, kind, cluster=f.cluster, link=f.link,
                             duration_s=float(f.duration_s))
            self.kernel.push(f.t + f.duration_s, LINK_UP,
                             cluster=f.cluster, link=f.link)
        elif isinstance(f, SatCrash):
            self.kernel.push(f.t, kind, sat=f.sat,
                             duration_s=float(f.duration_s))
            self.kernel.push(f.t + f.duration_s, SAT_REBOOT, sat=f.sat)
        elif isinstance(f, SatReboot):
            self.kernel.push(f.t, kind, sat=f.sat)
        elif isinstance(f, MasterFailure):
            self.kernel.push(f.t, kind, cluster=f.cluster)
        elif isinstance(f, (PayloadCorruption, PayloadLoss)):
            self.kernel.push(f.t, kind, cluster=f.cluster)
        elif isinstance(f, ClockDrift):
            self.kernel.push(f.t, kind, cluster=f.cluster,
                             skew_s=float(f.skew_s))
        elif isinstance(f, SilentCorruption):
            self.kernel.push(f.t, kind, cluster=f.cluster, mode=f.mode,
                             scale=float(f.scale), seed=int(f.seed))
        else:
            raise TypeError(f"unknown fault type {type(f).__name__}")

    def poll(self, ctx, plan, state, t: float) -> None:
        """Apply every fault event due at sim time <= t, in kernel
        order. Called by the engine at round boundaries — mid-round
        faults land at the next boundary (round granularity is the
        engine's accounting granularity; Transport reads the live view
        within the round)."""
        for ev in self.kernel.pop_until(t):
            self._apply(ev, ctx, plan, state)

    def _apply(self, ev, ctx, plan, state) -> None:
        obs, fs = ctx.obs, self.state
        if obs is not None:
            obs.fault(ev.kind, ev.t, cluster=ev.cluster, sat=ev.sat,
                      **ev.payload)
        if ev.kind == LINK_DOWN:
            key = (ev.payload["link"], ev.cluster)
            end = ev.t + float(ev.payload["duration_s"])
            fs.outage_until[key] = max(fs.outage_until.get(key, 0.0), end)
        elif ev.kind == LINK_UP:
            key = (ev.payload["link"], ev.cluster)
            if fs.outage_until.get(key, 0.0) <= ev.t:
                fs.outage_until.pop(key, None)
        elif ev.kind == SAT_CRASH:
            end = ev.t + float(ev.payload["duration_s"])
            fs.crashed[ev.sat] = max(fs.crashed.get(ev.sat, 0.0), end)
            # a crashed master cannot aggregate: re-elect immediately
            for kc in np.flatnonzero(state.masters == ev.sat):
                self._failover(ctx, plan, state, int(kc), ev.t,
                               reason=SAT_CRASH)
        elif ev.kind == SAT_REBOOT:
            if fs.crashed.get(ev.sat, 0.0) <= ev.t:
                fs.crashed.pop(ev.sat, None)
        elif ev.kind == MASTER_FAIL:
            self._failover(ctx, plan, state, int(ev.cluster), ev.t,
                           reason=MASTER_FAIL)
        elif ev.kind in (PAYLOAD_CORRUPT, PAYLOAD_LOSS):
            key = (ev.kind, ev.cluster)
            fs.payload_pending[key] = fs.payload_pending.get(key, 0) + 1
        elif ev.kind == SILENT_CORRUPT:
            # past the checksum: stash the descriptor; the engine applies
            # it to the delivered cluster model before the merge
            fs.silent_pending.append(
                {"cluster": ev.cluster, "mode": ev.payload["mode"],
                 "scale": float(ev.payload["scale"]),
                 "seed": int(ev.payload["seed"])})
        elif ev.kind == CLOCK_DRIFT:
            # re-sync cost: latency-only, through the one accounting
            # entry point so the observer mirror stays bit-exact
            ctx.transport.for_cluster(ev.cluster).wait(
                float(ev.payload["skew_s"]), cause="clock_drift")

    def _failover(self, ctx, plan, state, kc: int, t: float,
                  reason: str) -> None:
        """Re-elect cluster ``kc``'s master: the next StarMask-ranked
        member — highest LISL fan-out among members alive at ``t``,
        excluding the failed master (the same rule StarMask used for the
        original election). Intra-cluster uploads re-route automatically
        because mixing reads ``state.masters``. With no live alternative
        the old master is kept (fully-degraded cluster; uploads to it
        still account — the sim keeps moving rather than wedging)."""
        if kc >= len(state.masters):
            return
        old = int(state.masters[kc])
        members = plan.clusters[kc]
        alive = [int(i) for i in members
                 if int(i) != old and not self.state.down(int(i), t)]
        if not alive:
            if ctx.obs is not None:
                ctx.obs.recovery("failover_exhausted", t, cluster=kc,
                                 sat=old, reason=reason)
            return
        fanout = np.asarray(ctx.env.fanout, float)
        new = int(alive[int(np.argmax(fanout[alive]))])
        state.masters[kc] = new
        if ctx.obs is not None:
            ctx.obs.recovery("failover", t, cluster=kc, sat=new,
                             old_master=old, new_master=new, reason=reason)

    def apply_selection(self, ctx, sel, skip_state, kc: int,
                        t: float) -> int:
        """Skip-many under crashes: force every crashed engaged member
        out of the participant mask (they idle the full barrier, exactly
        like a Skip-One'd member) with fairness carryover on the
        Skip-One counters. Returns the number of members forced out."""
        down = [li for li, cid in enumerate(sel.ids)
                if self.state.down(int(cid), t)]
        forced = [li for li in down if sel.mask[li]]
        for li in forced:
            sel.mask[li] = False
            if skip_state is not None and hasattr(skip_state, "tau"):
                from repro.core.skipone import force_skip
                force_skip(skip_state, li)
        if forced and ctx.obs is not None:
            ctx.obs.recovery("skip_crashed", t, cluster=kc,
                             skipped=len(forced),
                             sats=[int(sel.ids[li]) for li in forced])
        return len(forced)

    def corrupt_result(self, ctx, model, result, sels):
        """Apply every pending ``SilentCorruption`` to this round's
        delivered cluster models (the executor's fresh ``result``,
        list OR stacked) — AFTER training, BEFORE the pacing merge:
        the link-layer checksum never saw anything wrong, so the
        poisoned update reaches the aggregation layer. Pure value
        transform: no ledger, wall-clock, or engine-RNG touch (target
        picks for cluster=None descriptors come from a private
        generator seeded by the descriptor), so attaching corruption
        cannot perturb accounting — the mirror ledger reconcile stays
        bit-exact by construction."""
        fs = self.state
        if not fs.silent_pending:
            return result
        pending, fs.silent_pending = list(fs.silent_pending), []
        K = len(sels)
        is_list = isinstance(result, list)
        if is_list:
            result = list(result)       # never mutate the executor's list
        for d in pending:
            kc = d.get("cluster")
            if kc is None or not 0 <= int(kc) < K:
                pick = np.random.default_rng(int(d.get("seed", 0)))
                kc = int(pick.integers(max(K, 1)))
            kc = int(kc)
            if K == 0:
                continue
            if is_list:
                result[kc] = _corrupt_tree(result[kc], d)
            else:
                import jax
                lane = jax.tree.map(lambda l: l[kc], result)
                lane = _corrupt_tree(lane, d)
                result = jax.tree.map(
                    lambda l, v: l.at[kc].set(v.astype(l.dtype)),
                    result, lane)
            if ctx.obs is not None:
                ctx.obs.fault("silent_corrupt_applied",
                              float(ctx.ledger.wall_clock_s), cluster=kc,
                              mode=d["mode"])
        return result

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        return {"kernel": self.kernel.state_dict(),
                "state": self.state.to_dict()}

    def load_state_dict(self, sd: Optional[dict]) -> None:
        """Restore a snapshot (or clear, when None — a reused injector
        starting a fresh session must not leak the previous campaign).
        Mutates the existing ``FaultState`` in place: Transport views
        built from it stay live."""
        if sd is None:
            self.kernel.reset()
            self.state.reset()
            return
        self.kernel.load_state_dict(sd["kernel"])
        self.state.load(sd["state"])


def as_injector(faults) -> Optional[FaultInjector]:
    """Engine-facing coercion: None | FaultSchedule | FaultInjector."""
    if faults is None or isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, FaultSchedule):
        return FaultInjector(faults)
    raise TypeError("faults must be a FaultSchedule or FaultInjector, "
                    f"got {type(faults).__name__}")
