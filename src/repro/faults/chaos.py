"""Chaos-smoke harness: seeded fault campaigns against live engines.

    PYTHONPATH=src python -m repro.faults.chaos --smoke

Runs the ``smoke_schedule`` campaign against a tiny constellation for
each preset (CroSatFL plus scenario-zoo variants, including an
event-kernel one) and asserts the recovery contracts of DESIGN.md §13:

* **no deadlock** — every faulted session runs to completion;
* **accounting stays exact** — the TracingObserver's mirror ledger
  reconciles bit-for-bit against the engine ledger UNDER faults (every
  retry joule and backoff second hit the trace exactly once);
* **recovery demonstrably happened** — the trace contains a master
  failover and charged retries (the smoke campaign lands a
  MasterFailure + LISL outage + crash + payload corruption at t=0);
* **the null campaign is free** — an attached EMPTY schedule leaves the
  ledger bit-identical to an unattached run (golden-path guarantee);
* **kill/resume is exact** — a faulted session checkpointed mid-campaign
  and resumed replays the uninterrupted faulted ledger bit-for-bit
  (pending fault events ride the checkpoint);
* **degradation is graceful** — the faulted model still evaluates to a
  finite, above-chance accuracy.

Artifacts (per-preset JSONL + Chrome traces with the fault timeline
track, and ``chaos_report.json``) land under ``results/chaos/`` — CI's
``chaos-smoke`` job uploads them. Exit code 0 iff every check passed.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax
import numpy as np

from repro.faults.model import (FaultSchedule, corruption_schedule,
                                smoke_schedule)
from repro.obs import get_logger

log = get_logger("faults.chaos")

# the smoke presets: plain sync, deadline pacing, and the
# discrete-event kernel replay — three different wall-clock regimes for
# the same fault campaign
PRESETS = ("CroSatFL", "CroSatFL-SemiSync", "CroSatFL-EventSync")

CHANCE_ACC = 0.10   # eurosat-sim is 10-class; graceful > chance floor

# silent-corruption campaign (DESIGN.md §14): same schedule, three
# aggregators. FedAvg has breakdown point 0 — one NaN lane poisons the
# cross-aggregation — while median/trimmed-mean hold as long as
# corrupted lanes stay a minority.
CORRUPT_AGGS = ("fedavg", "median", "trimmed_mean")
QUORUM_FRAC = 0.6     # with ~2 sats/cluster a crashed sat -> 0.5 < 0.6
ROBUST_MARGIN = 0.30  # pinned: robust aggs must beat FedAvg by this
# (empirical gap on the smoke setup is ~0.9: FedAvg's merge goes NaN ->
# ~chance accuracy, median/trimmed-mean stay at the clean ~0.99)


def tiny_setup(seed: int = 0, n_clients: int = 8, n_train: int = 400,
               n_test: int = 100):
    """CPU-container-sized constellation + image model (mirrors the
    benchmark smoke cell without importing benchmarks, which is not on
    the installed path)."""
    from repro.constellation import ConstellationEnv
    from repro.data.synth import iid_partition, make_dataset
    from repro.fl.client import ImageFLModel

    ds = make_dataset("eurosat-sim", n=n_train, seed=seed)
    test = make_dataset("eurosat-sim", n=n_test, seed=seed + 99)
    parts = iid_partition(len(ds.y), n_clients, seed)
    env = ConstellationEnv(
        n_clients=n_clients,
        n_samples=np.array([len(p) for p in parts], float),
        gpu_fraction=0.5, seed=seed)
    model = ImageFLModel(ds, parts, test)
    return env, model


def build_engine(preset: str, env, model, *, rounds: int = 3,
                 seed: int = 0, observer=None, faults=None,
                 aggregator="fedavg", quorum=None):
    from repro.core.starmask import StarMaskParams
    from repro.fl.engine import (EngineConfig, make_crosatfl,
                                 make_scenario)

    cfg = EngineConfig(rounds=rounds, local_epochs=1, c_flop=5e7,
                       model_bits=model.model_bits(), seed=seed,
                       aggregator=aggregator, quorum=quorum)
    sm = StarMaskParams(k_max=4, m_min=2)
    if preset == "CroSatFL":
        return make_crosatfl(cfg, env, model, starmask=sm,
                             observer=observer, faults=faults)
    return make_scenario(preset, cfg, env, model, starmask=sm,
                         observer=observer, faults=faults)


def _final_acc(history) -> float:
    return float(history[-1]["acc"]) if history else float("nan")


def run_preset(preset: str, seed: int = 0, rounds: int = 3,
               out_dir: str | None = None) -> dict:
    """One preset's full chaos campaign; returns the check dict."""
    from repro.obs import TracingObserver

    env, model = tiny_setup(seed=seed)
    ev = lambda p, r: model.evaluate(p)   # noqa: E731
    checks: dict = {}

    # 1. clean reference (unattached — the golden path)
    _, led_clean, hist_clean = build_engine(
        preset, env, model, rounds=rounds, seed=seed).run(
        eval_fn=ev, eval_every=rounds)

    # 2. attached-but-empty schedule must be bit-free
    _, led_null, _ = build_engine(
        preset, env, model, rounds=rounds, seed=seed,
        faults=FaultSchedule()).run(eval_fn=ev, eval_every=rounds)
    checks["null_schedule_bitfree"] = (dataclasses.asdict(led_null)
                                       == dataclasses.asdict(led_clean))

    # 3. the faulted run: traced, checkpointed every round
    schedule = smoke_schedule(seed=seed, n_clusters=4, n_clients=8)
    jsonl = (os.path.join(out_dir, f"{preset}.faulted.jsonl")
             if out_dir else None)
    obs = TracingObserver(jsonl)
    ck = os.path.join(out_dir, f"ck_{preset}") if out_dir else None
    eng = build_engine(preset, env, model, rounds=rounds, seed=seed,
                       observer=obs, faults=schedule)
    _, led_faulted, hist_faulted = eng.run(eval_fn=ev, eval_every=rounds,
                                           ckpt_dir=ck)
    checks["completed"] = True           # reaching here == no deadlock
    checks["mirror_exact_under_faults"] = obs.reconcile(led_faulted)["exact"]
    recov = [e for e in obs.tracer.events if e["kind"] == "recovery"]
    checks["failover_in_trace"] = any(e["action"] == "failover"
                                      for e in recov)
    checks["retries_charged"] = (
        obs.metrics.total("recoveries", action="retry") >= 1
        and obs.metrics.total("wait_s", cause="retry") > 0)
    checks["faults_applied"] = obs.metrics.total("faults") >= 4
    acc_c, acc_f = _final_acc(hist_clean), _final_acc(hist_faulted)
    checks["graceful_degradation"] = (np.isfinite(acc_f)
                                      and acc_f >= CHANCE_ACC / 2)
    if out_dir:
        obs.tracer.to_chrome_trace(
            os.path.join(out_dir, f"{preset}.faulted.trace.json"))

    # 4. kill mid-campaign, resume from the round-1 boundary: the
    # resumed faulted ledger must equal the uninterrupted one
    if ck is not None and rounds > 1:
        from repro.ckpt import load_session
        step = os.path.join(ck, "step_1")
        with open(os.path.join(step, "meta.json")) as f:
            meta = json.load(f)
        assert meta.get("faults") is not None, "faults_state missing in ckpt"
        like = model.stack([model.init(jax.random.PRNGKey(0))]
                           * len(meta["masters"]))
        st = load_session(step, like)
        eng2 = build_engine(preset, env, model, rounds=rounds, seed=seed,
                            faults=smoke_schedule(seed=seed, n_clusters=4,
                                                  n_clients=8))
        _, led_res, _ = eng2.run(eval_fn=ev, eval_every=rounds, state=st)
        checks["resume_bitexact_under_faults"] = (
            dataclasses.asdict(led_res) == dataclasses.asdict(led_faulted))

    ok = all(checks.values())
    return {"preset": preset, "ok": ok, "checks": checks,
            "acc_clean": acc_c, "acc_faulted": acc_f,
            "faults_applied": int(obs.metrics.total("faults")),
            "recovery_actions": {lbl.get("action", "?"): int(v)
                                 for lbl, v in
                                 obs.metrics.series("recoveries")},
            "dropped_transfers": int(eng.faults.state.dropped)}


def run_corruption(seed: int = 0, rounds: int = 3,
                   out_dir: str | None = None,
                   preset: str = "CroSatFL") -> dict:
    """Silent-corruption campaign: one seeded schedule (two NaN-splat
    lanes + a crashed sat holding one cluster below quorum + a Poisson
    tail), run under each aggregator in ``CORRUPT_AGGS`` with the same
    quorum gate. Checks that the corruption reaches the merge, that the
    mirror ledger stays bit-exact (corruption is a value-layer fault —
    it must never touch accounting), that quorum/degraded events land in
    the trace, and that the robust aggregators beat FedAvg's final
    accuracy by ``ROBUST_MARGIN``."""
    from repro.obs import TracingObserver

    env, model = tiny_setup(seed=seed)
    ev = lambda p, r: model.evaluate(p)   # noqa: E731
    checks: dict = {}
    accs: dict[str, float] = {}
    for agg in CORRUPT_AGGS:
        sch = corruption_schedule(seed=seed, n_clusters=4, n_clients=8)
        jsonl = (os.path.join(out_dir, f"corrupt_{agg}.jsonl")
                 if out_dir else None)
        obs = TracingObserver(jsonl)
        eng = build_engine(preset, env, model, rounds=rounds, seed=seed,
                           observer=obs, faults=sch,
                           aggregator=agg, quorum=QUORUM_FRAC)
        _, led, hist = eng.run(eval_fn=ev, eval_every=rounds)
        accs[agg] = _final_acc(hist)
        checks[f"mirror_exact_{agg}"] = obs.reconcile(led)["exact"]
        qevents = [e for e in obs.tracer.events if e["kind"] == "quorum"]
        checks[f"quorum_in_trace_{agg}"] = len(qevents) >= 1
        checks[f"degraded_counted_{agg}"] = (
            eng.quorum is not None and eng.quorum.degraded >= 1
            and any(not e["ok"] for e in qevents))
        checks[f"corruption_applied_{agg}"] = any(
            e["kind"] == "fault" and e["fkind"] == "silent_corrupt_applied"
            for e in obs.tracer.events)
        if agg != "fedavg":
            # the robust path must have actually *rejected* the NaN
            # lanes, not merely happened to dodge them
            checks[f"nonfinite_rejected_{agg}"] = (
                obs.metrics.total("robust_rejects", reason="nonfinite")
                >= 1)
        if out_dir:
            obs.tracer.to_chrome_trace(
                os.path.join(out_dir, f"corrupt_{agg}.trace.json"))

    base = accs["fedavg"] if np.isfinite(accs["fedavg"]) else 0.0
    for agg in CORRUPT_AGGS[1:]:
        checks[f"{agg}_beats_fedavg"] = (
            np.isfinite(accs[agg]) and accs[agg] - base >= ROBUST_MARGIN)
    return {"preset": preset, "aggregators": list(CORRUPT_AGGS),
            "quorum": QUORUM_FRAC, "margin": ROBUST_MARGIN,
            "acc": accs, "ok": all(checks.values()), "checks": checks}


def run_campaign(presets=PRESETS, seed: int = 0, rounds: int = 3,
                 out_dir: str = "results/chaos") -> int:
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for preset in presets:
        log.info(f"chaos: {preset} (seed={seed}, rounds={rounds})")
        res = run_preset(preset, seed=seed, rounds=rounds, out_dir=out_dir)
        for name, passed in res["checks"].items():
            log.info(f"  {'ok ' if passed else 'BAD'} {name}")
        log.info(f"  acc clean={res['acc_clean']:.3f} "
                 f"faulted={res['acc_faulted']:.3f} "
                 f"faults={res['faults_applied']} "
                 f"recoveries={res['recovery_actions']}")
        results.append(res)
    log.info(f"chaos: silent-corruption campaign (seed={seed})")
    corrupt = run_corruption(seed=seed, rounds=rounds, out_dir=out_dir)
    for name, passed in corrupt["checks"].items():
        log.info(f"  {'ok ' if passed else 'BAD'} {name}")
    log.info("  acc " + " ".join(f"{a}={v:.3f}"
                                 for a, v in corrupt["acc"].items()))
    report = {"seed": seed, "rounds": rounds,
              "ok": all(r["ok"] for r in results) and corrupt["ok"],
              "presets": results, "corruption": corrupt}
    path = os.path.join(out_dir, "chaos_report.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    log.info(f"wrote {path}")
    n_ok = sum(r["ok"] for r in results)
    log.info(f"chaos: {n_ok}/{len(results)} presets ok")
    return 0 if report["ok"] else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="seeded fault-injection campaign (DESIGN.md §13)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: all presets, tiny setup")
    ap.add_argument("--presets", nargs="*", default=None,
                    help=f"subset of {PRESETS}")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--out", default="results/chaos")
    args = ap.parse_args(argv)
    presets = args.presets if args.presets else PRESETS
    unknown = sorted(set(presets) - set(PRESETS))
    if unknown:
        log.warn(f"unknown presets {unknown} (choose from {PRESETS})")
        return 2
    return run_campaign(presets, seed=args.seed, rounds=args.rounds,
                        out_dir=args.out)


if __name__ == "__main__":
    sys.exit(main())
