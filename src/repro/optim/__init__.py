from repro.optim.optimizers import (adamw_init, adamw_update, sgd_init,
                                    sgd_update)  # noqa: F401
from repro.optim.compression import (int8_compress, int8_decompress,
                                     topk_compress, topk_decompress)  # noqa: F401
