"""Gradient / payload compression for LISL exchanges.

Two schemes, both with exact byte accounting for the energy model:

* ``int8`` — symmetric per-chunk quantization (FedOrbit-style reduced
  precision; also the beyond-paper compressed cross-aggregation payload).
  4x smaller than fp32, 2x smaller than bf16.
* ``topk`` — magnitude top-k sparsification with index+value encoding
  (classic distributed-optimization trick; used in the beyond-paper
  experiments for the inter-cluster hop).

The Pallas kernel in kernels/quant fuses the quantize path; this module is
the reference implementation plus the pytree plumbing.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32
CHUNK = 1024


def _pad_to(x, mult):
    pad = (-x.shape[0]) % mult
    return jnp.pad(x, (0, pad)), pad


def int8_compress(tree: Any, chunk: int = CHUNK):
    """Leaf -> {"q": int8 (n_chunks, chunk), "scale": f32 (n_chunks,),
    "shape", "pad"}. Bytes = n + 4 * n_chunks."""
    def comp(x):
        flat = x.reshape(-1).astype(F32)
        flat, pad = _pad_to(flat, chunk)
        blocks = flat.reshape(-1, chunk)
        scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(F32),
                "shape": x.shape, "pad": pad}
    return jax.tree.map(comp, tree)


def int8_decompress(ctree: Any, dtype=F32):
    def dec(c):
        flat = (c["q"].astype(F32) * c["scale"][:, None]).reshape(-1)
        n = math.prod(c["shape"])
        return flat[:n].reshape(c["shape"]).astype(dtype)
    return jax.tree.map(dec, ctree,
                        is_leaf=lambda t: isinstance(t, dict) and "q" in t)


def int8_bytes(tree: Any, chunk: int = CHUNK) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = leaf.size
        total += n + 4 * math.ceil(n / chunk)
    return total


def topk_compress(tree: Any, frac: float = 0.05):
    """Keep the top ``frac`` entries by magnitude per leaf."""
    def comp(x):
        flat = x.reshape(-1).astype(F32)
        k = max(1, int(flat.size * frac))
        val, idx = jax.lax.top_k(jnp.abs(flat), k)
        return {"idx": idx.astype(jnp.int32), "val": flat[idx],
                "shape": x.shape, "size": flat.size}
    return jax.tree.map(comp, tree)


def topk_decompress(ctree: Any, dtype=F32):
    def dec(c):
        flat = jnp.zeros((c["size"],), F32).at[c["idx"]].set(c["val"])
        return flat.reshape(c["shape"]).astype(dtype)
    return jax.tree.map(dec, ctree,
                        is_leaf=lambda t: isinstance(t, dict) and "idx" in t)


def topk_bytes(tree: Any, frac: float = 0.05) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        k = max(1, int(leaf.size * frac))
        total += 8 * k          # 4B index + 4B value
    return total
