"""Minimal functional optimizers (no optax in the container).

All operate on parameter pytrees; state is a pytree of the same structure.
Used by the FL clients (SGD-momentum, paper-style local training) and the
datacenter train driver (AdamW).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------

def sgd_init(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=F32), params)


def sgd_update(params, grads, state, *, lr: float, momentum: float = 0.9,
               weight_decay: float = 0.0):
    def upd(p, g, m):
        gf = g.astype(F32)
        if weight_decay:
            gf = gf + weight_decay * p.astype(F32)
        m2 = momentum * m + gf
        return (p.astype(F32) - lr * m2).astype(p.dtype), m2

    flat = jax.tree.map(upd, params, grads, state)
    new_p = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, new_m


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamState:
    z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=F32), params)
    return AdamState(z(), z(), jnp.zeros((), jnp.int32))


def adamw_update(params, grads, state: AdamState, *, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.01):
    count = state.count + 1
    cf = count.astype(F32)

    def mom(m, g):
        return b1 * m + (1 - b1) * g.astype(F32)

    def var(v, g):
        gf = g.astype(F32)
        return b2 * v + (1 - b2) * gf * gf

    mu = jax.tree.map(mom, state.mu, grads)
    nu = jax.tree.map(var, state.nu, grads)
    bc1 = 1 - b1 ** cf
    bc2 = 1 - b2 ** cf

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        step = step + weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * step).astype(p.dtype)

    return jax.tree.map(upd, params, mu, nu), AdamState(mu, nu, count)
