"""Checkpoint / restore: pytrees -> per-leaf npz shards with a manifest.

Fault-tolerance contract (DESIGN.md §6):

* ``save_pytree``/``load_pytree`` — any JAX pytree of arrays. Leaves are
  stored under stable path-keys so a checkpoint written by one process
  layout restores under another (elastic resume).
* ``save_session``/``load_session`` — full CroSatFL SessionState
  (cluster models + Skip-One fairness counters + masters + BOTH RNG
  streams (JAX key and host numpy bit-generator state) + energy ledger +
  round index + the pacing policy's straggler stash, when one is
  pending), written at edge-round boundaries. A restarted session
  continues from the latest cluster models — exactly the paper's
  master-migration property — and replays the uninterrupted session
  bit-for-bit (tests/test_session.py pins this).
* Writes are atomic (tmp + rename) so a crash mid-write never corrupts
  the latest checkpoint; the manifest carries a crc32 over the leaf
  contents, so a torn or bit-rotted shard is DETECTED on load
  (``CheckpointCorrupt``) instead of silently resuming from garbage.
  ``load_latest_session`` walks step dirs newest-first and falls back to
  the last good round boundary when the newest checkpoint is corrupt.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import zipfile
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import EnergyLedger


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed its integrity check: the manifest's content
    checksum does not match the stored leaves (torn write / bit rot), or
    the archive itself is unreadable. Callers that can fall back should
    resume from the previous step dir (``load_latest_session``)."""


def _content_crc(keys, arrays) -> int:
    """crc32 over (key, leaf bytes) pairs in manifest order."""
    crc = 0
    for k, a in zip(keys, arrays):
        crc = zlib.crc32(k.encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return crc


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


def save_pytree(tree: Any, path: str) -> None:
    keys, leaves, _ = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    manifest = {"keys": keys, "n": len(leaves),
                "crc32": _content_crc(keys, [arrays[f"leaf_{i}"]
                                             for i in range(len(leaves))])}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".npz")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, manifest=json.dumps(manifest), **arrays)
    os.replace(tmp, path)


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (keys must match).

    Raises ``CheckpointCorrupt`` when the archive is unreadable or the
    stored leaves fail the manifest's crc32 (checkpoints written before
    the checksum existed load unverified — the field is optional)."""
    try:
        with np.load(path, allow_pickle=False) as z:
            manifest = json.loads(str(z["manifest"]))
            stored = [z[f"leaf_{i}"] for i in range(manifest["n"])]
    except (zipfile.BadZipFile, ValueError, KeyError) as e:
        raise CheckpointCorrupt(f"{path}: unreadable archive ({e})") from e
    want = manifest.get("crc32")
    if want is not None:
        got = _content_crc(manifest["keys"], stored)
        if got != want:
            raise CheckpointCorrupt(
                f"{path}: content checksum mismatch "
                f"(manifest crc32={want}, stored leaves crc32={got}); "
                "torn or corrupted checkpoint")
    keys_like, _, treedef = _flatten_with_paths(like)
    if manifest["keys"] != keys_like:
        # elastic restore: match by key name
        by_key = dict(zip(manifest["keys"], stored))
        leaves = [jnp.asarray(by_key[k]) for k in keys_like]
    else:
        leaves = [jnp.asarray(a) for a in stored]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Session state
# ---------------------------------------------------------------------------

def save_session(state, path: str) -> None:
    """state: core.session.SessionState."""
    from repro.core.skipone import SkipOneState
    os.makedirs(path, exist_ok=True)
    save_pytree(state.cluster_models, os.path.join(path, "models.npz"))
    # pacing-policy cross-round state (SemiSyncPacing's straggler stash:
    # kc -> deferred fresh cluster model) rides next to the models so a
    # semi-sync resume is exact even with an update pending (DESIGN.md §8)
    pstate = getattr(state, "pacing_state", None)
    pending = pstate.get("pending") if isinstance(pstate, dict) else None
    if pending:
        save_pytree({str(kc): w for kc, w in pending.items()},
                    os.path.join(path, "pacing.npz"))
    # every non-"pending" pacing_state key is JSON-able by contract
    # (event-driven pacing: kernel tie-break RNG state, virtual clocks,
    # per-cluster last-sync times — repro.sim.driver) and rides in meta;
    # sessions without extras keep the exact pre-existing meta schema
    extras = ({k: v for k, v in pstate.items() if k != "pending"}
              if isinstance(pstate, dict) else {})
    meta = {
        "round_idx": state.round_idx,
        "masters": state.masters.tolist(),
        "rng_key": np.asarray(state.rng_key).tolist(),
        # host numpy bit-generator state (PCG64 dict of arbitrary-precision
        # ints — JSON-exact): without it a resumed session draws different
        # selection jitter / group samples than the uninterrupted one
        "host_rng": state.rng_state,
        "pacing_pending": sorted(int(kc) for kc in pending) if pending else [],
        **({"pacing_extras": extras} if extras else {}),
        # attached-fault-campaign snapshot (FaultInjector.state_dict():
        # pending fault kernel + live outage/crash view) — key absent on
        # fault-free sessions so their meta schema is byte-identical to
        # pre-faults checkpoints
        **({"faults": state.faults_state}
           if getattr(state, "faults_state", None) is not None else {}),
        "ledger": dataclasses.asdict(state.ledger),
        "skip": [{"kappa": s.kappa.tolist(), "tau": s.tau.tolist(),
                  "phi": s.phi.tolist()} for s in state.skip_states],
    }
    tmp = os.path.join(path, ".meta.tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f)
    os.replace(tmp, os.path.join(path, "meta.json"))


def load_session(path: str, models_like) -> "SessionState":
    from repro.core.session import SessionState
    from repro.core.skipone import SkipOneState
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    models = load_pytree(os.path.join(path, "models.npz"), models_like)
    skip = [SkipOneState(np.array(s["kappa"]), np.array(s["tau"]),
                         np.array(s["phi"])) for s in meta["skip"]]
    ledger = EnergyLedger(**meta["ledger"])
    pacing_state = dict(meta.get("pacing_extras") or {})
    pend_keys = meta.get("pacing_pending") or []
    if pend_keys:
        # every stashed model shares the single-cluster-model structure
        single_like = jax.tree.map(lambda l: l[0], models_like)
        loaded = load_pytree(os.path.join(path, "pacing.npz"),
                             {str(kc): single_like for kc in pend_keys})
        pacing_state["pending"] = {int(kc): loaded[str(kc)]
                                   for kc in pend_keys}
    if not pacing_state:
        pacing_state = None
    return SessionState(
        round_idx=meta["round_idx"], cluster_models=models,
        skip_states=skip, masters=np.array(meta["masters"]),
        rng_key=jnp.asarray(np.array(meta["rng_key"], np.uint32)),
        ledger=ledger,
        rng_state=meta.get("host_rng"),   # None on pre-field checkpoints
        pacing_state=pacing_state,
        faults_state=meta.get("faults"))  # None on fault-free sessions


def _step_dirs(directory: str) -> list[str]:
    """step_<n> dirs with a meta.json, newest first."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(directory, name, "meta.json")):
            try:
                steps.append((int(name.split("_")[1]), name))
            except ValueError:
                continue
    return [os.path.join(directory, name)
            for _, name in sorted(steps, reverse=True)]


def latest_checkpoint(directory: str) -> Optional[str]:
    """Newest valid step dir (named ``step_<n>``) under ``directory``."""
    steps = _step_dirs(directory)
    return steps[0] if steps else None


def load_latest_session(directory: str, models_like):
    """Resume from the newest LOADABLE step dir under ``directory``.

    Walks step dirs newest-first; a step whose shards fail the crc32
    check (torn write, bit rot) or whose meta.json is unreadable is
    skipped, falling back to the previous round boundary — the crash
    recovery contract of DESIGN.md §13. Returns ``(state, path)``, or
    ``(None, None)`` when no step loads. Raises nothing on corruption;
    structural mismatches against ``models_like`` still propagate."""
    for step in _step_dirs(directory):
        try:
            return load_session(step, models_like), step
        except (CheckpointCorrupt, json.JSONDecodeError, OSError):
            continue
    return None, None
