from repro.ckpt.store import (CheckpointCorrupt, latest_checkpoint,
                              load_latest_session, load_pytree,
                              load_session, save_pytree,
                              save_session)  # noqa: F401
