from repro.ckpt.store import (load_pytree, load_session, save_pytree,
                              save_session)  # noqa: F401
