from repro.data.synth import (SynthImageDataset, SynthLMDataset,
                              dirichlet_partition)  # noqa: F401
