"""Synthetic datasets + non-IID partitioner.

The container is offline, so MNIST/CIFAR-10/EuroSAT are modeled by
synthetic image-classification tasks with the same tensor geometry and a
controllable difficulty knob: class-conditional signal templates + noise.
A model must genuinely learn the class templates to exceed chance, so
convergence curves behave qualitatively like the real datasets (fast
"MNIST-like" at high SNR, slow "CIFAR-like" at low SNR).

``dirichlet_partition`` reproduces the paper's non-IID split (alpha = 0.5).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SynthImageDataset:
    """Class-templates + Gaussian noise image dataset."""
    x: np.ndarray          # (N, H, W, C) float32
    y: np.ndarray          # (N,) int
    n_classes: int
    name: str = "synth"

    @staticmethod
    def make(name: str = "eurosat-sim", n: int = 4000, n_classes: int = 10,
             hw: int = 16, c: int = 3, snr: float = 1.0,
             seed: int = 0, template_seed: int = 1234) -> "SynthImageDataset":
        """snr: template amplitude over unit noise. mnist-sim: snr 2.0;
        cifar-sim: snr 0.6; eurosat-sim: snr 1.0.

        ``template_seed`` fixes the class templates (the "true" task) so
        train/test splits generated with different ``seed`` values share the
        same classes; ``seed`` only drives sampling noise."""
        trng = np.random.default_rng(template_seed + hash(name) % 2 ** 16)
        rng = np.random.default_rng(seed)
        templates = trng.normal(0, 1, (n_classes, hw, hw, c)).astype(np.float32)
        # low-pass the templates (images have spatial structure)
        for _ in range(2):
            templates = (templates
                         + np.roll(templates, 1, 1) + np.roll(templates, -1, 1)
                         + np.roll(templates, 1, 2) + np.roll(templates, -1, 2)) / 5
        templates /= np.abs(templates).max((1, 2, 3), keepdims=True)
        y = rng.integers(0, n_classes, n)
        x = snr * templates[y] + rng.normal(0, 1, (n, hw, hw, c)).astype(np.float32)
        return SynthImageDataset(x.astype(np.float32), y.astype(np.int32),
                                 n_classes, name)

    def __len__(self) -> int:
        return len(self.y)


DATASET_PRESETS = {
    "mnist-sim": dict(hw=14, c=1, snr=2.0, n_classes=10),
    "cifar10-sim": dict(hw=16, c=3, snr=0.6, n_classes=10),
    "eurosat-sim": dict(hw=16, c=3, snr=1.0, n_classes=10),
}


def make_dataset(name: str, n: int = 4000, seed: int = 0) -> SynthImageDataset:
    kw = DATASET_PRESETS[name]
    return SynthImageDataset.make(name=name, n=n, seed=seed, **kw)


@dataclass
class SynthLMDataset:
    """Markov-chain token stream — tiny-LM FL runs."""
    tokens: np.ndarray     # (N, S) int32
    vocab: int

    @staticmethod
    def make(n: int = 2048, seq: int = 64, vocab: int = 128,
             seed: int = 0) -> "SynthLMDataset":
        rng = np.random.default_rng(seed)
        # sparse row-stochastic transition matrix -> learnable bigram structure
        trans = rng.dirichlet(np.full(vocab, 0.05), size=vocab)
        toks = np.zeros((n, seq), np.int32)
        state = rng.integers(0, vocab, n)
        for s in range(seq):
            toks[:, s] = state
            cum = np.cumsum(trans[state], -1)
            state = (cum > rng.random((n, 1))).argmax(-1)
        return SynthLMDataset(toks, vocab)


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 8) -> list[np.ndarray]:
    """Paper's non-IID split: per-class Dirichlet(alpha) shares per client.
    alpha -> inf approaches IID; paper uses alpha = 0.5."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        idx = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            members = np.flatnonzero(labels == c)
            rng.shuffle(members)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(members)).astype(int)[:-1]
            for i, part in enumerate(np.split(members, cuts)):
                idx[i].extend(part.tolist())
        if min(len(i) for i in idx) >= min_size:
            return [np.array(sorted(i), dtype=np.int64) for i in idx]


def iid_partition(n_items: int, n_clients: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_items)
    return [np.sort(p) for p in np.array_split(perm, n_clients)]
