"""CroSatFL core: the paper's contribution.

starmask  — RL-based LISL-feasible clustering (Alg. 1)
skipone   — per-round single-straggler skipping (Alg. 2)
crossagg  — random-k cross-aggregation + consolidation (Eq. 34-38)
energy    — computation / LISL / GS energy + latency model (Eq. 2-13)
session   — full on-orbit session controller (GS bootstrap -> R edge
            rounds -> consolidation -> GS downlink)
"""
from repro.core import crossagg, energy, skipone, starmask  # noqa: F401
