"""Random-k cross-aggregation (paper §IV-C, Eq. 34-38).

Cluster masters hold models w_k. In each edge round every master samples up
to ``k_nbr`` reachable masters from the instantaneous cross-plane LISL
topology and takes a sample-size-weighted average over {self} + sample.

Two equivalent implementations:

* ``mixing_matrix`` + ``apply_mixing`` — builds the (K, K) row-stochastic
  matrix M with M[k, j] = N_j / sum_{l in group_k} N_l and applies it to
  stacked models ``(K, ...)``. This is the jittable/datacenter form: one
  einsum per leaf (and the Pallas ``cross_agg`` kernel fuses it).
* ``sample_groups`` — host-side sampling used by the constellation
  simulation (numpy RNG on the observed reachability graph).

Consolidation (Eq. 38) is the special case of one group containing all
clusters.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Sampling (Eq. 35-36)
# ---------------------------------------------------------------------------

def sample_groups(reach: np.ndarray, k_nbr: int,
                  rng: np.random.Generator) -> list[np.ndarray]:
    """Per-cluster mixing groups M_k = {k} ∪ N_k (Eq. 36).

    reach: (K, K) bool reachability of master graph at this edge round
    (diagonal ignored). Samples min(k_nbr, |reach_k|) neighbors uniformly
    without replacement (Eq. 35).
    """
    K = reach.shape[0]
    groups = []
    for k in range(K):
        nbrs = np.flatnonzero(reach[k] & (np.arange(K) != k))
        take = min(k_nbr, nbrs.size)
        sel = rng.choice(nbrs, size=take, replace=False) if take else np.array([], int)
        groups.append(np.concatenate([[k], sel]).astype(int))
    return groups


# ---------------------------------------------------------------------------
# Mixing matrix (Eq. 37)
# ---------------------------------------------------------------------------

def mixing_matrix(groups: Sequence[np.ndarray], n_samples: np.ndarray) -> np.ndarray:
    """Row-stochastic (K, K): row k averages over group_k weighted by N_j."""
    K = len(groups)
    M = np.zeros((K, K), np.float64)
    for k, g in enumerate(groups):
        w = n_samples[g].astype(np.float64)
        M[k, g] = w / w.sum()
    return M


def mixing_matrix_jax(reach: jax.Array, n_samples: jax.Array, k_nbr: int,
                      key: jax.Array) -> jax.Array:
    """Jittable Eq. 35-37: per-row uniform sample of k_nbr reachable
    neighbors via Gumbel top-k over the reach mask, then N_j-weighted
    row normalization. reach: (K,K) bool; n_samples: (K,) float."""
    K = reach.shape[0]
    eye = jnp.eye(K, dtype=bool)
    cand = reach & ~eye
    g = jax.random.gumbel(key, (K, K))
    # rank candidates per row; non-candidates get -inf
    scores = jnp.where(cand, g, -jnp.inf)
    thresh = -jnp.sort(-scores, axis=1)[:, k_nbr - 1] if k_nbr > 0 else jnp.inf
    chosen = cand & (scores >= thresh[:, None]) if k_nbr > 0 else jnp.zeros_like(cand)
    sel = chosen | eye                                   # {k} ∪ N_k
    w = jnp.where(sel, n_samples[None, :].astype(F32), 0.0)
    return w / w.sum(axis=1, keepdims=True)


def apply_mixing(M, stacked_models, backend: str = "einsum"):
    """w'_k = sum_j M[k,j] w_j for every leaf of the stacked (K, ...) pytree.

    ``backend="pallas"`` routes through the fused ``kernels/cross_agg``
    tile kernel instead of the per-leaf matmul: leaves are concatenated
    into one (K, N_total) buffer so the whole model stack streams through
    HBM once (interpret mode off-TPU; parity vs this reference pinned in
    tests/test_kernels.py).
    """
    leaves = jax.tree.leaves(stacked_models)
    if leaves and leaves[0].shape[0] == 0:
        return stacked_models        # zero-participant round: nothing to mix
    if backend == "pallas":
        from repro.kernels.cross_agg import cross_agg_tree
        return cross_agg_tree(jnp.asarray(M, F32), stacked_models,
                              interpret=jax.default_backend() != "tpu")
    if backend != "einsum":
        raise ValueError(f"unknown mixing backend {backend!r}")
    Mj = jnp.asarray(M, F32)

    def mix(leaf):
        flat = leaf.reshape(leaf.shape[0], -1)
        out = (Mj @ flat.astype(F32)).astype(leaf.dtype)
        return out.reshape(leaf.shape)

    return jax.tree.map(mix, stacked_models)


# ---------------------------------------------------------------------------
# Consolidation (Eq. 38)
# ---------------------------------------------------------------------------

def consolidate(stacked_models, n_samples):
    """w_final = sum_k (N_k / sum N) w_k."""
    w = jnp.asarray(n_samples, F32)
    w = w / w.sum()

    def avg(leaf):
        flat = leaf.reshape(leaf.shape[0], -1).astype(F32)
        return (w @ flat).astype(leaf.dtype).reshape(leaf.shape[1:])

    return jax.tree.map(avg, stacked_models)


# ---------------------------------------------------------------------------
# Gossip consensus (beyond-paper: GS-free finalization)
# ---------------------------------------------------------------------------

def metropolis_matrix(reach: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings consensus weights on the (symmetric) reach
    graph: M[i,j] = 1/(1+max(deg_i, deg_j)) on edges, diagonal takes the
    remainder. Symmetric and doubly stochastic by construction, so its
    ``consensus_contraction`` (with uniform pi) is < 1 exactly when the
    graph is connected — the standard gossip-averaging operator used by
    GS-free finalization (fl/engine/mixing.GossipMixing)."""
    K = reach.shape[0]
    adj = np.asarray(reach, bool) & np.asarray(reach, bool).T
    np.fill_diagonal(adj, False)
    deg = adj.sum(1)
    M = np.zeros((K, K), np.float64)
    for i in range(K):
        for j in np.flatnonzero(adj[i]):
            M[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        M[i, i] = 1.0 - M[i].sum()
    return M


# ---------------------------------------------------------------------------
# Gossip diagnostics (beyond-paper: consensus-rate bound)
# ---------------------------------------------------------------------------

def consensus_contraction(M: np.ndarray, n_samples: np.ndarray) -> float:
    """Second-largest singular value of the pi-weighted mixing operator —
    an upper bound on per-round disagreement contraction. Used by tests and
    the convergence benchmark to sanity-check that random-k mixing actually
    propagates information (sigma_2 < 1 on a connected average graph)."""
    pi = n_samples / n_samples.sum()
    # project out the consensus direction in the pi-weighted inner product
    P = np.eye(len(pi)) - np.outer(np.ones_like(pi), pi)
    return float(np.linalg.svd(P @ M @ P, compute_uv=False)[0])
