"""End-to-end energy / latency model (paper §III-B, §III-C, Eq. 2-13).

All quantities are SI (seconds, joules, bits). Functions are pure and
vectorized over satellites so round-level accounting is a handful of
`jnp`/`np` reductions; the session controller (core/session.py) sums them
into the Table-II ledger.

Hardware profiles come from constellation/hardware.py; link rates/latencies
from constellation/lisl.py + gs.py. Paper parameter values (Table I) are the
defaults in LinkParams.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

CPU, GPU = 0, 1  # hardware type codes (h_i)


# ---------------------------------------------------------------------------
# Parameters (paper Table I defaults)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LinkParams:
    """Communication constants. Rates in bit/s, powers in W, delays in s."""
    lisl_rate: float = 16e6          # paper data rate: 16 Mbps
    gs_rate: float = 8e6             # GS: half LISL (bandwidth 1.25 vs 2.5 GHz)
    lisl_power: float = 10.0         # LISL Tx power (laser terminals ~10 W)
    gs_power: float = 40.0           # paper transmission power p = 40 W
    light_speed: float = 299_792_458.0


@dataclass(frozen=True)
class HardwareProfile:
    """Per-satellite compute profile x_i (paper Eq. 2-4, 8-9).

    alpha: effective FLOP/s throughput; cycles_per_sample C_i^CPU;
    freq f_i^CPU (Hz); kappa: switched capacitance gamma_i;
    gpu_power P_i^avg (W).
    """
    hw_type: int                     # CPU | GPU
    alpha: float                     # FLOP/s
    cycles_per_sample: float = 4e7   # C_i^CPU
    freq: float = 1.5e9              # f_i^CPU
    kappa: float = 1e-27             # gamma_i (effective switched capacitance)
    gpu_power: float = 30.0          # P_i^avg (space-rated GPU, e.g. Jetson class)


# ---------------------------------------------------------------------------
# Computation (Eq. 2-4, 7-11)
# ---------------------------------------------------------------------------

def flops_per_epoch(n_samples, c_flop: float):
    """Eq. 2: FLOPs_i = n_i * c_flop."""
    return np.asarray(n_samples, np.float64) * c_flop


def t_comp(n_samples, c_flop: float, alpha):
    """Eq. 4: per-epoch runtime = FLOPs_i / alpha_i."""
    return flops_per_epoch(n_samples, c_flop) / np.asarray(alpha, np.float64)


def t_train(n_samples, c_flop: float, alpha, local_epochs: int):
    """Eq. 3: T_i^train = L_loc * T_i^comp."""
    return local_epochs * t_comp(n_samples, c_flop, alpha)


def e_train(n_samples, c_flop: float, profiles, local_epochs: int):
    """Eq. 7-11: per-round computation energy per satellite.

    CPU: gamma * C_cpu * N_i * f^2   (Eq. 8) with N_i = L_loc * n_i (Eq. 7)
    GPU: P_avg * T_train             (Eq. 9)
    """
    n = np.asarray(n_samples, np.float64)
    N_i = local_epochs * n                                     # Eq. 7
    hw = np.array([p.hw_type for p in profiles])
    kappa = np.array([p.kappa for p in profiles])
    cyc = np.array([p.cycles_per_sample for p in profiles])
    freq = np.array([p.freq for p in profiles])
    gpu_p = np.array([p.gpu_power for p in profiles])
    alpha = np.array([p.alpha for p in profiles])

    e_cpu = kappa * cyc * N_i * freq ** 2                      # Eq. 8
    e_gpu = gpu_p * t_train(n, c_flop, alpha, local_epochs)    # Eq. 9
    return np.where(hw == CPU, e_cpu, e_gpu)                   # Eq. 10/11


# ---------------------------------------------------------------------------
# Communication (Eq. 5-6, 12-13)
# ---------------------------------------------------------------------------

def t_lisl(d_bits: float, rate, distance_m, lp: LinkParams):
    """Eq. 5: d/R + L (propagation).  Unreachable -> inf handled by caller."""
    return d_bits / np.asarray(rate, np.float64) + \
        np.asarray(distance_m, np.float64) / lp.light_speed


def e_lisl(d_bits: float, rate, distance_m, lp: LinkParams):
    """Eq. 12: P_lisl * T_lisl."""
    return lp.lisl_power * t_lisl(d_bits, rate, distance_m, lp)


def t_gs(d_bits: float, rate, distance_m, lp: LinkParams):
    """Eq. 6: d/R_gs + L_gs."""
    return d_bits / np.asarray(rate, np.float64) + \
        np.asarray(distance_m, np.float64) / lp.light_speed


def e_gs(d_bits: float, rate, distance_m, lp: LinkParams):
    """Eq. 13: P_gs * T_gs (effective power covers up+downlink)."""
    return lp.gs_power * t_gs(d_bits, rate, distance_m, lp)


# ---------------------------------------------------------------------------
# Ledger: running account of a session (feeds Table II / Fig. 4)
# ---------------------------------------------------------------------------

def _reject_bad(method: str, **vals) -> None:
    """A NaN or negative contribution (corrupted payload, bad codec
    scale) would silently poison every downstream total — fail at the
    entry point instead. ``not (v >= 0)`` is one comparison that catches
    both NaN and negative; zero is a legal contribution."""
    bad = {k: v for k, v in vals.items() if not (v >= 0)}
    if bad:
        raise ValueError(f"EnergyLedger.{method}: NaN/negative "
                         + ", ".join(f"{k}={v!r}" for k, v in bad.items()))


@dataclass
class EnergyLedger:
    intra_lisl_count: int = 0
    inter_lisl_count: int = 0
    gs_count: int = 0
    lisl_energy_j: float = 0.0
    gs_energy_j: float = 0.0
    train_energy_j: float = 0.0
    transmission_time_s: float = 0.0   # serial link occupancy
    compute_time_s: float = 0.0        # sum of per-round barriers (makespan-ish)
    waiting_time_s: float = 0.0        # latency-only (no energy, §III-C)
    wall_clock_s: float = 0.0

    def add_intra(self, n: int, e_j: float, t_s: float):
        if not (n >= 0 and e_j >= 0 and t_s >= 0):
            _reject_bad("add_intra", n=n, e_j=e_j, t_s=t_s)
        self.intra_lisl_count += n
        self.lisl_energy_j += e_j
        self.transmission_time_s += t_s

    def add_inter(self, n: int, e_j: float, t_s: float):
        if not (n >= 0 and e_j >= 0 and t_s >= 0):
            _reject_bad("add_inter", n=n, e_j=e_j, t_s=t_s)
        self.inter_lisl_count += n
        self.lisl_energy_j += e_j
        self.transmission_time_s += t_s

    def add_gs(self, n: int, e_j: float, t_s: float):
        if not (n >= 0 and e_j >= 0 and t_s >= 0):
            _reject_bad("add_gs", n=n, e_j=e_j, t_s=t_s)
        self.gs_count += n
        self.gs_energy_j += e_j
        self.transmission_time_s += t_s

    def add_train(self, e_j: float, barrier_s: float):
        if not (e_j >= 0 and barrier_s >= 0):
            _reject_bad("add_train", e_j=e_j, barrier_s=barrier_s)
        self.train_energy_j += e_j
        self.compute_time_s += barrier_s

    def add_wait(self, t_s: float):
        if not (t_s >= 0):
            _reject_bad("add_wait", t_s=t_s)
        self.waiting_time_s += t_s

    @property
    def transmission_energy_j(self) -> float:
        return self.lisl_energy_j + self.gs_energy_j

    @property
    def total_energy_j(self) -> float:
        return self.transmission_energy_j + self.train_energy_j

    def row(self) -> dict:
        """Table-II row."""
        return {
            "intra_lisl": self.intra_lisl_count,
            "inter_lisl": self.inter_lisl_count,
            "gs_comm": self.gs_count,
            "tx_energy_kj": self.transmission_energy_j / 1e3,
            "train_energy_kj": self.train_energy_j / 1e3,
            "tx_time_h": self.transmission_time_s / 3600,
            "waiting_h": self.waiting_time_s / 3600,
            "wall_clock_h": self.wall_clock_s / 3600,
        }

    def snapshot(self) -> dict:
        """Raw SI field values (unlike ``row``, no unit rescaling) — the
        reconciliation surface for repro.obs: an observer mirror is
        bit-exact iff its snapshot equals the session ledger's."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(EnergyLedger)}

    def merged(self, other: "EnergyLedger") -> "EnergyLedger":
        out = dataclasses.replace(self)
        for f in dataclasses.fields(EnergyLedger):
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        return out
