"""CroSatFL session controller (paper §IV, Fig. 1) — legacy facade.

The orchestration itself lives in the pluggable round engine
(``repro.fl.engine``): ``Session`` is now ``RoundEngine`` + the CroSatFL
policy quadruple (StarMask clustering x Skip-One selection x random-k
cross-aggregation x identity codec). The engine owns the canonical round
skeleton and the uniform energy/latency accounting shared with all five
baselines; see DESIGN.md §7 and fl/engine/engine.py.

This module keeps the original public API — ``SessionConfig``,
``SessionState`` (checkpointed by ckpt/store.py), ``Session.run`` — so
examples/, benchmarks/, and tests keep importing it unchanged. Golden
parity with the pre-refactor loop is pinned by
tests/test_engine_parity.py.

The session flow (engine + CroSatFL policies):

  1. GS bootstrap: broadcast w0 to all participating satellites when they
     enter the Canberra visibility window (1 GS comm per cluster master —
     masters relay over LISLs).
  2. StarMask clustering from satellite profiles + LISL feasibility.
  3. R edge rounds, each: Skip-One selection, local training, intra-cluster
     upload to master + weighted FedAvg, random-k cross-aggregation among
     reachable masters, uniform ledger accounting.
  4. On-orbit consolidation (Eq. 38) + single GS downlink.

Checkpoint/restart: ``SessionState`` is a plain pytree-of-arrays +
dataclass state; ``ckpt/`` serializes it at edge-round boundaries. Master
migration is state-free by construction — the cluster model lives in the
(replicated) session state, so a new master "continues from the latest
cluster model" (paper §III-A).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core import skipone
from repro.core.energy import EnergyLedger
from repro.core.starmask import Instance, StarMaskParams
from repro.fl.engine import EngineConfig, SessionState, make_crosatfl

__all__ = ["Session", "SessionConfig", "SessionState"]


@dataclass(frozen=True)
class SessionConfig:
    edge_rounds: int = 40            # R (paper Table I)
    main_rounds: int = 1             # G
    local_epochs: int = 10           # L_loc
    k_nbr: int = 2                   # random-k sampling parameter
    c_flop: Any = 5e7                # FLOPs/sample, or "measured:<arch>/<shape>"
    model_bits: float = 8 * 44.7e6   # payload d (ResNet-18 fp32 ~ 44.7 MB)
    seed: int = 0
    batched_exec: bool = False       # DEPRECATED: use executor="batched"
    executor: Any = None             # round execution mode (repro.fl.exec)
    aggregator: Any = "fedavg"       # merge-time robustness (repro.fl.robust)
    quorum: Any = None               # min valid fraction per cluster commit
    retry_base_s: Optional[float] = None   # transport retry overrides
    retry_max_attempts: Optional[int] = None
    skip_one: skipone.SkipOneParams = field(default_factory=skipone.SkipOneParams)
    starmask: StarMaskParams = field(default_factory=StarMaskParams)

    def engine_config(self) -> EngineConfig:
        return EngineConfig(rounds=self.edge_rounds,
                            local_epochs=self.local_epochs,
                            c_flop=self.c_flop, model_bits=self.model_bits,
                            seed=self.seed, batched_exec=self.batched_exec,
                            executor=self.executor,
                            aggregator=self.aggregator, quorum=self.quorum,
                            retry_base_s=self.retry_base_s,
                            retry_max_attempts=self.retry_max_attempts)


class Session:
    """One CroSatFL session over a simulated constellation.

    See fl/engine/engine.py for the ``env`` and ``model`` duck-types.
    """

    RELAY_FALLBACK_M = 3e6   # nominal relayed path when instantaneously cut

    def __init__(self, cfg: SessionConfig, env, model, observer=None,
                 faults=None):
        self.engine = make_crosatfl(cfg.engine_config(), env, model,
                                    k_nbr=cfg.k_nbr, skip_one=cfg.skip_one,
                                    starmask=cfg.starmask,
                                    observer=observer, faults=faults)
        self.cfg, self.env, self.model = cfg, env, model
        self.rng = self.engine.rng

    def make_instance(self) -> Instance:
        """The StarMask problem instance for this env (profiles + LISL
        energy matrix); exposed for notebooks/benchmarks."""
        ctx = self.engine._make_ctx(EnergyLedger())
        return self.engine.clustering.make_instance(ctx)

    def run(self, rounds: Optional[int] = None,
            eval_fn: Optional[Callable] = None,
            state: Optional[SessionState] = None,
            policy_params: Optional[dict] = None,
            ckpt_dir: Optional[str] = None,
            ckpt_every: int = 1,
            eval_every: int = 1,
            ) -> tuple[Any, EnergyLedger, list[dict]]:
        self.engine.clustering.policy_params = policy_params
        return self.engine.run(rounds=rounds, eval_fn=eval_fn, state=state,
                               ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                               eval_every=eval_every)
