"""CroSatFL session controller (paper §IV, Fig. 1).

Orchestrates one full session over the constellation simulation:

  1. GS bootstrap: broadcast w0 to all participating satellites when they
     enter the Canberra visibility window (1 GS comm per cluster master —
     masters relay over LISLs; the paper's "18 GS communications" for 9
     clusters = 9 bootstrap + 9 collection).
  2. StarMask clustering from satellite profiles + LISL feasibility.
  3. R edge rounds, each:
       a. Skip-One participant selection per cluster,
       b. local training (L_loc epochs) on participants,
       c. intra-cluster upload to master + weighted FedAvg,
       d. random-k cross-aggregation among reachable masters,
     with full energy/latency accounting into an EnergyLedger.
  4. On-orbit consolidation (Eq. 38) + single GS downlink.

The training itself is delegated to an ``FLModel`` adapter (fl/client.py),
so the same controller drives both the paper-faithful CNN-on-EuroSAT-style
runs and the tiny-LM runs used in tests.

Checkpoint/restart: ``SessionState`` is a plain pytree-of-arrays +
dataclass state; ``ckpt/`` serializes it at edge-round boundaries. Master
migration is state-free by construction — the cluster model lives in the
(replicated) session state, so a new master "continues from the latest
cluster model" (paper §III-A).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crossagg, skipone
from repro.core.energy import (CPU, GPU, EnergyLedger, HardwareProfile,
                               LinkParams, e_gs, e_lisl, e_train, t_gs,
                               t_lisl, t_train)
from repro.core.starmask import (ClusteringResult, Instance, StarMaskParams,
                                 cluster as starmask_cluster)


@dataclass(frozen=True)
class SessionConfig:
    edge_rounds: int = 40            # R (paper Table I)
    main_rounds: int = 1             # G
    local_epochs: int = 10           # L_loc
    k_nbr: int = 2                   # random-k sampling parameter
    c_flop: float = 5e7              # FLOPs per sample (model-dependent)
    model_bits: float = 8 * 44.7e6   # payload d (ResNet-18 fp32 ~ 44.7 MB)
    seed: int = 0
    skip_one: skipone.SkipOneParams = field(default_factory=skipone.SkipOneParams)
    starmask: StarMaskParams = field(default_factory=StarMaskParams)


@dataclass
class SessionState:
    """Everything needed to restart mid-session (ckpt/ serializes this)."""
    round_idx: int
    cluster_models: Any              # stacked (K, ...) pytree
    skip_states: list[skipone.SkipOneState]
    masters: np.ndarray              # (K,) current master satellite ids
    rng_key: jax.Array
    ledger: EnergyLedger


class Session:
    """One CroSatFL session over a simulated constellation.

    ``env`` duck-type (constellation/sim.py provides it):
        n_clients, profiles: list[HardwareProfile], n_samples: (n,),
        link_params: LinkParams,
        lisl_distance(i, j, t) -> meters | inf,
        master_reach(t) -> (K, K) bool given cluster assignment,
        gs_window_wait(sat, t) -> (wait_s, distance_m),
        intra_cluster_distances(cluster, master, t) -> (m,) meters
    ``model`` duck-type (fl/client.py):
        init(key) -> params
        local_train(params, client_id, epochs, key) -> (params', metrics)
        stack(list_of_params) -> stacked pytree;  unstack inverse
    """

    def __init__(self, cfg: SessionConfig, env, model):
        self.cfg, self.env, self.model = cfg, env, model
        self.rng = np.random.default_rng(cfg.seed)

    # -- clustering ---------------------------------------------------------
    def make_instance(self) -> Instance:
        env, cfg = self.env, self.cfg
        n = env.n_clients
        alpha = np.array([p.alpha for p in env.profiles])
        tt = t_train(env.n_samples, cfg.c_flop, alpha, cfg.local_epochs)
        et = e_train(env.n_samples, cfg.c_flop, env.profiles, cfg.local_epochs)
        lisl_e = np.zeros((n, n))
        for i in range(n):
            for j in range(n):
                dist = env.lisl_distance(i, j, 0.0)
                lisl_e[i, j] = (e_lisl(cfg.model_bits, env.link_params.lisl_rate,
                                       dist, env.link_params)
                                if np.isfinite(dist) else 1e9)
        return Instance(
            share=env.n_samples / env.n_samples.sum(),
            hw=np.array([p.hw_type for p in env.profiles]),
            t_comp=tt / cfg.local_epochs,
            e_train=et,
            fanout=np.asarray(env.fanout),
            lisl_e=lisl_e,
        )

    # -- session ------------------------------------------------------------
    def run(self, rounds: Optional[int] = None,
            eval_fn: Optional[Callable] = None,
            state: Optional[SessionState] = None,
            policy_params: Optional[dict] = None,
            ) -> tuple[Any, EnergyLedger, list[dict]]:
        cfg, env = self.cfg, self.env
        R = rounds if rounds is not None else cfg.edge_rounds
        key = jax.random.PRNGKey(cfg.seed)

        inst = self.make_instance()
        key, sub = jax.random.split(key)
        result = starmask_cluster(inst, cfg.starmask, sub, params=policy_params)
        assert result.feasible, f"StarMask infeasible, K_min={result.k_min}"
        clusters = result.clusters
        K = len(clusters)
        N_k = np.array([env.n_samples[c].sum() for c in clusters], np.float64)

        lp = env.link_params
        d = cfg.model_bits

        if state is None:
            # ---- GS bootstrap: one downlink per cluster master ------------
            ledger = EnergyLedger()
            key, sub = jax.random.split(key)
            w0 = self.model.init(sub)
            masters = np.array([c[np.argmax(inst.fanout[c])] for c in clusters])
            t_now = 0.0
            for mk in masters:
                wait, dist = env.gs_window_wait(int(mk), t_now)
                ledger.add_wait(wait)
                ledger.add_gs(1, e_gs(d, lp.gs_rate, dist, lp),
                              t_gs(d, lp.gs_rate, dist, lp))
            # master relays w0 inside its cluster over LISLs
            for c, mk in zip(clusters, masters):
                for i in c:
                    if i == mk:
                        continue
                    dist = self._dist(int(mk), int(i), t_now)
                    ledger.add_intra(1, e_lisl(d, lp.lisl_rate, dist, lp),
                                     t_lisl(d, lp.lisl_rate, dist, lp))
            cluster_models = self.model.stack([w0] * K)
            state = SessionState(
                round_idx=0, cluster_models=cluster_models,
                skip_states=[skipone.SkipOneState.init(len(c)) for c in clusters],
                masters=masters, rng_key=key, ledger=ledger)
        ledger = state.ledger
        key = state.rng_key

        alpha = np.array([p.alpha for p in env.profiles])
        tt_full = t_train(env.n_samples, cfg.c_flop, alpha, cfg.local_epochs)
        et_full = e_train(env.n_samples, cfg.c_flop, env.profiles,
                          cfg.local_epochs)
        hw_rare = self._hw_penalty(inst)

        history: list[dict] = []
        wall = ledger.wall_clock_s
        for r in range(state.round_idx, R):
            t_now = wall
            round_barrier = 0.0
            new_models = []
            models_list = self.model.unstack(state.cluster_models, K)
            for kc, (c, w_k) in enumerate(zip(clusters, models_list)):
                # --- Skip-One (Eq. 26-33) ---------------------------------
                jitter = self.rng.lognormal(0.0, 0.25, len(c))  # transient load
                tt_r = tt_full[c] * jitter
                mask, state.skip_states[kc] = skipone.select(
                    tt_r, et_full[c], hw_rare[c], state.skip_states[kc],
                    cfg.skip_one, r)
                part = c[mask]
                # --- local training (participants only) --------------------
                key, sub = jax.random.split(key)
                w_new = self.model.cluster_round(
                    w_k, part, env.n_samples[part], cfg.local_epochs, sub)
                new_models.append(w_new)
                # --- accounting --------------------------------------------
                barrier = tt_r[mask].max() if mask.any() else 0.0
                ledger.add_train(float(et_full[c][mask].sum()), float(barrier))
                # skipped satellites idle at the barrier: latency-only wait
                ledger.add_wait(float((barrier - tt_r[mask]).sum()
                                      if mask.any() else 0.0))
                round_barrier = max(round_barrier, float(barrier))
                mk = state.masters[kc]
                for i in part:
                    if i == mk:
                        continue
                    dist = env.lisl_distance(int(i), int(mk), t_now)
                    if not np.isfinite(dist):
                        # master migration: re-designate a reachable member
                        mk = self._migrate(c, i, t_now)
                        state.masters[kc] = mk
                        dist = self._dist(int(i), int(mk), t_now)
                    ledger.add_intra(1, e_lisl(d, lp.lisl_rate, dist, lp),
                                     t_lisl(d, lp.lisl_rate, dist, lp))

            stacked = self.model.stack(new_models)

            # --- random-k cross-aggregation (Eq. 34-37) ---------------------
            reach = env.master_reach(state.masters, t_now)
            groups = crossagg.sample_groups(reach, cfg.k_nbr, self.rng)
            M = crossagg.mixing_matrix(groups, N_k)
            stacked = crossagg.apply_mixing(M, stacked)
            for kc, g in enumerate(groups):
                for j in g:
                    if j == kc:
                        continue
                    dist = self._dist(int(state.masters[j]),
                                      int(state.masters[kc]), t_now)
                    ledger.add_inter(1, e_lisl(d, lp.lisl_rate, dist, lp),
                                     t_lisl(d, lp.lisl_rate, dist, lp))

            state.cluster_models = stacked
            state.round_idx = r + 1
            state.rng_key = key
            wall += round_barrier
            ledger.wall_clock_s = wall

            if eval_fn is not None:
                w_glob = crossagg.consolidate(stacked, N_k)
                m = eval_fn(w_glob, r)
                m["round"] = r
                m.update(ledger.row())
                history.append(m)

        # ---- consolidation (Eq. 38) + final GS downlink --------------------
        w_final = crossagg.consolidate(state.cluster_models, N_k)
        for mk in state.masters:
            wait, dist = env.gs_window_wait(int(mk), wall)
            ledger.add_wait(wait)
            ledger.add_gs(1, e_gs(d, lp.gs_rate, dist, lp),
                          t_gs(d, lp.gs_rate, dist, lp))
        return w_final, ledger, history

    # -- helpers -------------------------------------------------------------
    RELAY_FALLBACK_M = 3e6   # nominal relayed path when instantaneously cut

    def _dist(self, i: int, j: int, t: float) -> float:
        d = self.env.lisl_distance(i, j, t)
        return d if np.isfinite(d) else self.RELAY_FALLBACK_M

    def _hw_penalty(self, inst: Instance) -> np.ndarray:
        """H_i: rare hardware is expensive to skip (Eq. 33)."""
        frac_gpu = inst.hw.mean()
        rare_gpu = 1.0 - frac_gpu
        return np.where(inst.hw == GPU, rare_gpu, frac_gpu)

    def _migrate(self, cluster_ids: np.ndarray, from_sat: int, t_now: float):
        """Pick the member reachable from ``from_sat`` with max fan-out."""
        best, best_fo = cluster_ids[0], -1
        for j in cluster_ids:
            if j == from_sat:
                continue
            if np.isfinite(self.env.lisl_distance(int(from_sat), int(j), t_now)):
                fo = self.env.fanout[j]
                if fo > best_fo:
                    best, best_fo = j, fo
        return int(best)
