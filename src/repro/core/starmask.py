"""StarMask: RL-based clustering with action masking (paper §IV-A, Alg. 1).

Finite-horizon MDP: one satellite assigned per step to an existing cluster
(actions 1..K_max) or a new one (action K_max+1). The policy is a pointer-
style single-head attention over (satellite query x cluster summaries)
(Eq. 24), trained with advantage actor-critic (Eq. 21) on the terminal
reward (Eq. 17). Action masking Γ (Eq. 22) enforces:

  * master feasibility  |C_k| - 1 <= max_j c~_j          (Eq. 23)
  * optional hardware homogeneity (else penalized via M_mix)
  * OPENNEW masked at K = K_max
  * completion feasibility: remaining satellites can still fill every
    instantiated cluster to m_min and fit within remaining capacity.

Deterministic greedy fallback constructs the smallest feasible partition
(descending per-epoch runtime, first-fit) and reports K_min (Eq. 25) when
nothing is feasible.

Pure JAX policy; episode rollout is a host loop (N <= a few hundred).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
NEG = -1e9

N_SAT_FEATS = 5       # share, hw, t_comp, e_train, fanout  (x_i)
N_CL_FEATS = 8        # size, t_min, t_max, e_sum, share_sum, gpu_frac, cap_left, active


# ---------------------------------------------------------------------------
# Problem instance
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StarMaskParams:
    k_max: int = 12
    m_min: int = 2
    hw_homogeneous: bool = False   # hard constraint vs M_mix penalty
    # reward coefficients (Eq. 17) — fixed across experiments
    theta_wait: float = 1.0
    beta: float = 1.0
    gamma: float = 1.0
    nu_k: float = 0.1
    lam_mix: float = 0.5
    # hardware-dependent cap on manageable members for a master (Eq. 25)
    l_cpu: int = 6
    l_gpu: int = 10


@dataclass
class Instance:
    """Satellite profiles x_i (+ link-energy matrix for E_tot)."""
    share: np.ndarray        # (N,) n_i / sum n
    hw: np.ndarray           # (N,) 0=CPU 1=GPU
    t_comp: np.ndarray       # (N,) per-epoch seconds
    e_train: np.ndarray      # (N,) per-round joules
    fanout: np.ndarray       # (N,) c_i
    lisl_e: Optional[np.ndarray] = None   # (N,N) intra-cluster link energy

    @property
    def n(self) -> int:
        return len(self.share)

    def feats(self) -> np.ndarray:
        f = np.stack([self.share, self.hw.astype(float),
                      self.t_comp, self.e_train, self.fanout.astype(float)], 1)
        # min-max normalize continuous columns for the policy net
        out = f.copy()
        for c in (0, 2, 3, 4):
            lo, hi = f[:, c].min(), f[:, c].max()
            out[:, c] = (f[:, c] - lo) / (hi - lo) if hi > lo else 0.0
        return out.astype(np.float32)


def effective_capacity(inst: Instance, p: StarMaskParams) -> np.ndarray:
    """c~_i = min(c_i - 1, L_{h_i})  (Eq. 25)."""
    L = np.where(inst.hw == 1, p.l_gpu, p.l_cpu)
    return np.minimum(inst.fanout - 1, L)


def k_min(inst: Instance, p: StarMaskParams) -> int:
    """Lower bound on clusters: greedily take best-capacity masters."""
    cap = np.sort(effective_capacity(inst, p))[::-1]
    covered, k = 0, 0
    while covered < inst.n and k < inst.n:
        covered += cap[k] + 1     # master + c~ members
        k += 1
    return k if covered >= inst.n else inst.n + 1   # n+1 => infeasible


# ---------------------------------------------------------------------------
# Partition bookkeeping + action masking Γ (Eq. 22-23)
# ---------------------------------------------------------------------------

class PartialPartition:
    def __init__(self, inst: Instance, p: StarMaskParams):
        self.inst, self.p = inst, p
        self.assign = np.full(inst.n, -1, int)
        self.members: list[list[int]] = [[] for _ in range(p.k_max)]
        self.k_open = 0
        self.cap = effective_capacity(inst, p)

    def cluster_capacity(self, k: int) -> int:
        """Max members supportable: best member acts as master (Eq. 23)."""
        m = self.members[k]
        return int(max(self.cap[m]) + 1) if m else 0

    def feasible_actions(self, t: int) -> np.ndarray:
        """Mask over K_max + 1 actions for satellite t."""
        inst, p = self.inst, self.p
        n_left = inst.n - t                       # including t
        mask = np.zeros(p.k_max + 1, bool)
        # capacity if t opens/joins — t itself could be the master
        for k in range(self.k_open):
            m = self.members[k]
            new_cap = int(max(max(self.cap[m]), self.cap[t]) + 1)
            if len(m) + 1 > new_cap:
                continue                           # Eq. 23 violated
            if p.hw_homogeneous and any(inst.hw[j] != inst.hw[t] for j in m):
                continue
            mask[k] = True
        if self.k_open < p.k_max:
            mask[p.k_max] = True                   # OPENNEW
        # completion feasibility: after this assignment, can the remaining
        # n_left-1 satellites still (a) fill every open cluster to m_min and
        # (b) fit in remaining capacity?
        cap_max = int(self.cap.max() + 1)
        for a in np.flatnonzero(mask):
            opens = self.k_open + (1 if a == p.k_max else 0)
            deficit, cap_left = 0, 0
            for k in range(self.k_open):
                sz = len(self.members[k]) + (1 if a == k else 0)
                deficit += max(0, p.m_min - sz)
                cap_left += max(0, self.cluster_capacity(k)
                                + (1 if a == k and self.cap[t] + 1 >
                                   self.cluster_capacity(k) else 0) - sz)
            if a == p.k_max:
                deficit += max(0, p.m_min - 1)
                cap_left += cap_max - 1
            rem = n_left - 1
            extra_cap = (p.k_max - opens) * cap_max
            if deficit > rem or rem > cap_left + extra_cap:
                mask[a] = False
        return mask

    def apply(self, t: int, a: int):
        if a == self.p.k_max:
            a = self.k_open
            self.k_open += 1
        self.members[a].append(t)
        self.assign[t] = a

    def summaries(self) -> np.ndarray:
        """Φ(C_k) for all K_max slots (inactive slots zeroed)."""
        inst, p = self.inst, self.p
        out = np.zeros((p.k_max, N_CL_FEATS), np.float32)
        t_hi = inst.t_comp.max() or 1.0
        e_hi = inst.e_train.sum() or 1.0
        for k in range(self.k_open):
            m = self.members[k]
            cap = self.cluster_capacity(k)
            out[k] = [len(m) / inst.n,
                      inst.t_comp[m].min() / t_hi,
                      inst.t_comp[m].max() / t_hi,
                      inst.e_train[m].sum() / e_hi,
                      inst.share[m].sum(),
                      inst.hw[m].mean(),
                      (cap - len(m)) / inst.n,
                      1.0]
        return out

    def clusters(self) -> list[np.ndarray]:
        return [np.array(m, int) for m in self.members[: self.k_open]]


# ---------------------------------------------------------------------------
# Terminal reward (Eq. 17-20)
# ---------------------------------------------------------------------------

def reward(clusters: list[np.ndarray], inst: Instance, p: StarMaskParams,
           ) -> tuple[float, dict]:
    K = len(clusters)
    t = inst.t_comp
    W = sum(t[c].max() - t[c].min() for c in clusters)            # Eq. 18
    e_comp = float(inst.e_train.sum())
    e_link = 0.0
    if inst.lisl_e is not None:
        for c in clusters:
            if len(c) > 1:
                # members -> master (best-capacity member)
                master = c[np.argmax(effective_capacity(inst, p)[c])]
                e_link += float(inst.lisl_e[c, master].sum()
                                - inst.lisl_e[master, master])
    E_tot = e_comp + e_link
    shares = np.array([inst.share[c].sum() for c in clusters])
    var = float(((shares - shares.mean()) ** 2).mean())           # Eq. 19
    mix = sum(1 for c in clusters if len(set(inst.hw[c])) > 1)    # Eq. 20

    # min-max normalization ranges estimated from the instance
    W_hi = (t.max() - t.min()) * max(K, 1) or 1.0
    E_hi = inst.e_train.sum() * 2 or 1.0
    terms = {
        "W": W / W_hi, "E": E_tot / E_hi, "var": var * K ** 2,
        "K": K / p.k_max, "mix": mix / max(K, 1),
    }
    r = -(p.theta_wait * terms["W"] + p.beta * terms["E"] +
          p.gamma * terms["var"] + p.nu_k * terms["K"] +
          p.lam_mix * terms["mix"])                               # Eq. 17
    return float(r), terms


# ---------------------------------------------------------------------------
# Attention policy + value head (Eq. 24)
# ---------------------------------------------------------------------------

def policy_init(key: jax.Array, hidden: int = 32) -> dict:
    k = iter(jax.random.split(key, 12))
    g = lambda *s: jax.random.normal(next(k), s, F32) / math.sqrt(s[0])
    return {
        "sat_w": g(N_SAT_FEATS, hidden), "sat_b": jnp.zeros(hidden),
        "cl_w": g(N_CL_FEATS, hidden), "cl_b": jnp.zeros(hidden),
        "wq": g(hidden, hidden), "wk": g(hidden, hidden), "wv": g(hidden, hidden),
        "ptr_w": g(hidden, hidden),          # pointer scores per cluster
        "new_w": g(2 * hidden, 1),           # OPENNEW logit from [q, z]
        "val_w": g(2 * hidden, hidden), "val_b": jnp.zeros(hidden),
        "val_o": g(hidden, 1),
    }


def policy_apply(params: dict, sat_feat: jax.Array, cl_feats: jax.Array,
                 mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """sat_feat: (F,), cl_feats: (K_max, Fc), mask: (K_max+1,) bool.
    Returns (log_probs (K_max+1,), value ())."""
    s = jnp.tanh(sat_feat @ params["sat_w"] + params["sat_b"])    # (h,)
    c = jnp.tanh(cl_feats @ params["cl_w"] + params["cl_b"])      # (K,h)
    q = s @ params["wq"]
    kk = c @ params["wk"]
    v = c @ params["wv"]
    att = jax.nn.softmax(
        jnp.where(mask[:-1], kk @ q / math.sqrt(q.shape[0]), NEG))
    z = att @ v                                                   # Eq. 24
    ptr = (c @ params["ptr_w"]) @ q / math.sqrt(q.shape[0])       # (K,)
    new = (jnp.concatenate([q, z]) @ params["new_w"])[0]
    logits = jnp.concatenate([ptr, new[None]])
    logits = jnp.where(mask, logits, NEG)
    logp = jax.nn.log_softmax(logits)
    h = jnp.tanh(jnp.concatenate([q, z]) @ params["val_w"] + params["val_b"])
    value = (h @ params["val_o"])[0]
    return logp, value


_policy_jit = jax.jit(policy_apply)


# ---------------------------------------------------------------------------
# Greedy fallback (Alg. 1 line 10)
# ---------------------------------------------------------------------------

def greedy_fallback(inst: Instance, p: StarMaskParams,
                    ) -> Optional[list[np.ndarray]]:
    """Descending per-epoch runtime, first-fit into feasible clusters."""
    order = np.argsort(-inst.t_comp)
    pp = PartialPartition(inst, p)
    for t in order:
        # best-fit: prefer the feasible cluster with the closest mean t_comp
        placed = False
        best, best_gap = -1, np.inf
        for k in range(pp.k_open):
            m = pp.members[k]
            new_cap = int(max(max(pp.cap[m]), pp.cap[t]) + 1)
            if len(m) + 1 > new_cap:
                continue
            if p.hw_homogeneous and any(inst.hw[j] != inst.hw[t] for j in m):
                continue
            gap = abs(inst.t_comp[m].mean() - inst.t_comp[t])
            if gap < best_gap:
                best, best_gap = k, gap
        if best >= 0:
            pp.members[best].append(int(t)); pp.assign[t] = best
            placed = True
        elif pp.k_open < p.k_max:
            pp.members[pp.k_open].append(int(t)); pp.assign[t] = pp.k_open
            pp.k_open += 1
            placed = True
        if not placed:
            return None
    # m_min repair: merge undersized clusters into nearest feasible one
    clusters = pp.clusters()
    small = [c for c in clusters if len(c) < p.m_min]
    big = [c for c in clusters if len(c) >= p.m_min]
    for c in small:
        merged = False
        for i, b in enumerate(big):
            cap = int(effective_capacity(inst, p)[np.concatenate([b, c])].max() + 1)
            if len(b) + len(c) <= cap and (
                    not p.hw_homogeneous or len(set(inst.hw[np.concatenate([b, c])])) == 1):
                big[i] = np.concatenate([b, c])
                merged = True
                break
        if not merged:
            big.append(c)   # keep as-is (m_min soft-violated) rather than fail
    return big if big else None


# ---------------------------------------------------------------------------
# Rollout + A2C training (Eq. 21)
# ---------------------------------------------------------------------------

@dataclass
class ClusteringResult:
    clusters: list[np.ndarray]
    assign: np.ndarray
    reward: float
    terms: dict
    feasible: bool
    k_min: int
    used_fallback: bool = False


def rollout(params: dict, inst: Instance, p: StarMaskParams,
            key: jax.Array, greedy: bool = False):
    """One episode. Returns (result, trajectory) where trajectory carries
    (sat_feat, cl_feats, mask, action, logp_a, value) per step."""
    feats = inst.feats()
    pp = PartialPartition(inst, p)
    traj = []
    for t in range(inst.n):
        mask_np = pp.feasible_actions(t)
        if not mask_np.any():
            kmin = k_min(inst, p)
            if kmin > p.k_max:
                return ClusteringResult([], pp.assign, -np.inf, {},
                                        False, kmin), traj
            fb = greedy_fallback(inst, p)
            if fb is None:
                return ClusteringResult([], pp.assign, -np.inf, {},
                                        False, kmin), traj
            r, terms = reward(fb, inst, p)
            assign = np.full(inst.n, -1, int)
            for k, c in enumerate(fb):
                assign[c] = k
            return ClusteringResult(fb, assign, r, terms, True, kmin,
                                    used_fallback=True), traj
        cl = pp.summaries()
        mask = jnp.asarray(mask_np)
        logp, value = _policy_jit(params, jnp.asarray(feats[t]),
                                  jnp.asarray(cl), mask)
        if greedy:
            a = int(jnp.argmax(logp))
        else:
            key, sub = jax.random.split(key)
            a = int(jax.random.categorical(sub, logp))
        traj.append((feats[t], cl, mask_np, a, float(logp[a]), float(value)))
        pp.apply(t, a)

    clusters = pp.clusters()
    r, terms = reward(clusters, inst, p)
    return ClusteringResult(clusters, pp.assign, r, terms, True,
                            k_min(inst, p)), traj


def _a2c_loss(params, sat_f, cl_f, masks, actions, ret):
    """Batched over a whole episode (terminal-only reward => same return)."""
    logps, values = jax.vmap(lambda s, c, m: policy_apply(params, s, c, m)
                             )(sat_f, cl_f, masks)
    logp_a = jnp.take_along_axis(logps, actions[:, None], 1)[:, 0]
    adv = ret - values
    pol = -(logp_a * jax.lax.stop_gradient(adv)).mean()           # Eq. 21
    val = (adv ** 2).mean()
    ent = -(jnp.exp(logps) * jnp.where(jnp.isfinite(logps), logps, 0.0)
            ).sum(-1).mean()
    return pol + 0.5 * val - 0.01 * ent


_a2c_grad = jax.jit(jax.value_and_grad(_a2c_loss))


def train_policy(instances: list[Instance], p: StarMaskParams,
                 key: jax.Array, episodes: int = 300, lr: float = 3e-3,
                 ) -> tuple[dict, list[float]]:
    """A2C over random instances; returns (params, reward history)."""
    key, sub = jax.random.split(key)
    params = policy_init(sub)
    m = jax.tree.map(jnp.zeros_like, params)   # Adam moments
    v = jax.tree.map(jnp.zeros_like, params)
    hist = []
    b1, b2, eps = 0.9, 0.999, 1e-8
    for ep in range(episodes):
        inst = instances[ep % len(instances)]
        key, sub = jax.random.split(key)
        res, traj = rollout(params, inst, p, sub)
        if not traj or not res.feasible:
            continue
        hist.append(res.reward)
        sat_f = jnp.asarray(np.stack([s for s, *_ in traj]))
        cl_f = jnp.asarray(np.stack([c for _, c, *_ in traj]))
        masks = jnp.asarray(np.stack([mk for _, _, mk, *_ in traj]))
        acts = jnp.asarray(np.array([a for *_, a, _, _ in traj]))
        ret = jnp.float32(res.reward)
        _, grads = _a2c_grad(params, sat_f, cl_f, masks, acts, ret)
        t_ = ep + 1
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
        params = jax.tree.map(
            lambda pa, mm, vv: pa - lr * (mm / (1 - b1 ** t_)) /
            (jnp.sqrt(vv / (1 - b2 ** t_)) + eps), params, m, v)
    return params, hist


def cluster(inst: Instance, p: StarMaskParams, key: jax.Array,
            params: Optional[dict] = None, n_samples: int = 8,
            ) -> ClusteringResult:
    """Top-level StarMask entry: best-of-n sampled rollouts (or greedy
    decode when params given), greedy fallback when RL finds nothing."""
    if params is None:
        key, sub = jax.random.split(key)
        params = policy_init(sub)
    best: Optional[ClusteringResult] = None
    res, _ = rollout(params, inst, p, key, greedy=True)
    if res.feasible:
        best = res
    for i in range(n_samples):
        key, sub = jax.random.split(key)
        res, _ = rollout(params, inst, p, sub)
        if res.feasible and (best is None or res.reward > best.reward):
            best = res
    if best is None:
        kmin = k_min(inst, p)
        fb = greedy_fallback(inst, p) if kmin <= p.k_max else None
        if fb is None:
            return ClusteringResult([], np.full(inst.n, -1), -np.inf, {},
                                    False, kmin)
        r, terms = reward(fb, inst, p)
        assign = np.full(inst.n, -1, int)
        for k, c in enumerate(fb):
            assign[c] = k
        return ClusteringResult(fb, assign, r, terms, True, kmin, True)
    return best
