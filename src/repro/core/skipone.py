"""Skip-One client selection (paper §IV-B, Eq. 26-33, Algorithm 2).

Per cluster, per edge round: skip at most ONE satellite when the utility

    Psi({i}; r) = theta_T * dT_i + theta_E * dE_i - theta_H * H_i - theta_F * phi_i

is positive over the fairness-constrained admissible set

    U_k(r) = { i : kappa_i(r) = 0, tau_i(r) < tau_max }.

State per satellite: cooldown kappa (rounds until skippable again),
staleness tau (consecutive rounds skipped... tracked as rounds since last
participation), participation history phi (EMA of skip indicator).

Both a numpy host implementation (constellation sim) and a jittable mask
builder (datacenter fl_train_step) are provided; tests assert they agree.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SkipOneParams:
    theta_T: float = 1.0       # latency-reduction weight
    theta_E: float = 0.5       # energy-saving weight
    theta_H: float = 0.3       # hardware-rarity penalty weight
    theta_F: float = 0.5       # recent-skip fairness penalty weight
    cooldown: int = 2          # kappa reset: rounds barred after a skip
    tau_max: int = 4           # staleness bound (rounds since participation)
    phi_decay: float = 0.5     # EMA decay of the skip-history term
    all_participate_every: int = 10  # periodic full rounds reset counters


@dataclass
class SkipOneState:
    """Per-satellite fairness state (Eq. 31)."""
    kappa: np.ndarray          # (n,) cooldown counters
    tau: np.ndarray            # (n,) rounds since last participation
    phi: np.ndarray            # (n,) EMA of skip history

    @staticmethod
    def init(n: int) -> "SkipOneState":
        return SkipOneState(np.zeros(n, int), np.zeros(n, int), np.zeros(n))


def _normalize(x: np.ndarray) -> np.ndarray:
    rng = x.max() - x.min()
    return (x - x.min()) / rng if rng > 0 else np.zeros_like(x)


def select(t_train: np.ndarray, e_train: np.ndarray, hw_penalty: np.ndarray,
           state: SkipOneState, p: SkipOneParams, round_idx: int,
           ) -> tuple[np.ndarray, SkipOneState]:
    """Algorithm 2 for one cluster.

    t_train/e_train/hw_penalty: (n,) realized this round.
    Returns (participate_mask, new_state); at most one False in the mask.
    """
    n = len(t_train)
    participate = np.ones(n, bool)
    new = SkipOneState(state.kappa.copy(), state.tau.copy(), state.phi.copy())

    full_round = p.all_participate_every and \
        (round_idx % p.all_participate_every == p.all_participate_every - 1)
    if full_round:
        # periodic all-participation round resets cooldowns (paper §IV-B end)
        new.kappa[:] = 0
        new.tau[:] = 0
        new.phi *= p.phi_decay
        return participate, new

    admissible = (state.kappa == 0) & (state.tau < p.tau_max)        # Eq. 31
    skipped = -1
    if admissible.any() and n > 1:
        M = t_train.max()                                            # Eq. 27
        # counterfactual barrier per candidate (Eq. 28-29)
        order = np.argsort(t_train)
        second = t_train[order[-2]]
        dT = np.where(t_train == M, M - second, 0.0)                 # Eq. 29
        dE = e_train.copy()                                          # Eq. 30
        # normalize terms to comparable ranges (paper: min-max)
        psi = (p.theta_T * _normalize(dT) + p.theta_E * _normalize(dE)
               - p.theta_H * hw_penalty - p.theta_F * state.phi)     # Eq. 33
        psi = np.where(admissible, psi, -np.inf)
        i_star = int(np.argmax(psi))                                 # Eq. 32
        if np.isfinite(psi[i_star]) and psi[i_star] > 0:
            participate[i_star] = False
            skipped = i_star

    # state update
    new.kappa = np.maximum(state.kappa - 1, 0)
    if skipped >= 0:
        new.kappa[skipped] = p.cooldown
        new.tau[skipped] = state.tau[skipped] + 1
        new.phi[skipped] = state.phi[skipped] * p.phi_decay + (1 - p.phi_decay)
    part = participate
    new.tau = np.where(part, 0, new.tau)
    new.phi = np.where(part, state.phi * p.phi_decay, new.phi)
    return participate, new


# ---------------------------------------------------------------------------
# Jittable mask (datacenter path): same rule over (K, n) cluster-major arrays
# ---------------------------------------------------------------------------

def select_jax(t_train: jax.Array, e_train: jax.Array, hw_penalty: jax.Array,
               kappa: jax.Array, tau: jax.Array, phi: jax.Array,
               p: SkipOneParams) -> tuple[jax.Array, tuple[jax.Array, ...]]:
    """Vectorized over clusters: inputs (K, n). Returns (mask (K,n) f32,
    (kappa', tau', phi'))."""
    def _norm(x):
        lo = x.min(-1, keepdims=True)
        rng = x.max(-1, keepdims=True) - lo
        return jnp.where(rng > 0, (x - lo) / jnp.maximum(rng, 1e-30), 0.0)

    admissible = (kappa == 0) & (tau < p.tau_max)
    M = t_train.max(-1, keepdims=True)                               # Eq. 27
    top2 = -jnp.sort(-t_train, axis=-1)[:, 1:2]
    dT = jnp.where(t_train == M, M - top2, 0.0)                      # Eq. 29
    psi = (p.theta_T * _norm(dT) + p.theta_E * _norm(e_train)
           - p.theta_H * hw_penalty - p.theta_F * phi)               # Eq. 33
    psi = jnp.where(admissible, psi, -jnp.inf)
    i_star = jnp.argmax(psi, -1)                                     # Eq. 32
    do_skip = jnp.take_along_axis(psi, i_star[:, None], -1)[:, 0] > 0
    onehot = jax.nn.one_hot(i_star, t_train.shape[-1], dtype=bool) & do_skip[:, None]
    mask = ~onehot

    kappa2 = jnp.maximum(kappa - 1, 0)
    kappa2 = jnp.where(onehot, p.cooldown, kappa2)
    tau2 = jnp.where(onehot, tau + 1, 0)
    phi2 = jnp.where(onehot, phi * p.phi_decay + (1 - p.phi_decay),
                     phi * p.phi_decay)
    return mask.astype(jnp.float32), (kappa2, tau2, phi2)


def force_skip(state: SkipOneState, idx: int) -> None:
    """Externally-forced non-participation (a satellite crash,
    repro.faults) — the skip-MANY generalization's fairness carryover.

    Unlike a utility-chosen skip, a crash is not the policy's decision:
    staleness ``tau`` advances (another round without participation, so
    Eq. 31 keeps the member admissible-pressure when it reboots and
    Skip-One will not immediately utility-skip it again), but ``phi``
    (the skip-history EMA the utility penalizes) and ``kappa`` (the
    cooldown earned by being chosen) are left untouched. Mutates
    ``state`` in place; call after ``select`` has already applied its
    own update for the round.
    """
    state.tau[idx] = state.tau[idx] + 1


def barrier_reduction(t_train: np.ndarray, mask: np.ndarray) -> float:
    """Realized dT of this round's decision (for the ledger)."""
    M = t_train.max()
    M_post = t_train[mask].max() if mask.any() else 0.0
    return float(M - M_post)
