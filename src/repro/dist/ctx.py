"""Named sharding-rule context.

``use_rules(mesh, rules)`` activates a rule table; ``shard(x, name)`` then
applies ``jax.lax.with_sharding_constraint`` with the named PartitionSpec.
Outside any active context ``shard`` is an identity no-op, which is what
keeps ``models/`` mesh-agnostic: the same layer code traces on a bare CPU,
under the test meshes, and under the 512-device production meshes.

Contexts nest: an inner ``use_rules`` shadows the outer table for its
extent and restores it on exit (even on exception). Rules are consulted at
TRACE time, so entering the context inside a jitted function (as
``launch/steps.py`` does) is the intended usage.

Axes named by a rule that the array cannot actually be split over — the
axis is missing from the mesh, or the dim is not divisible by the axis
size — are dropped rather than erroring, so one rule table serves both the
full-size archs and the tiny ``reduced()`` smoke configs.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Mapping

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import fit_axes

# Stack of (mesh, rules) — thread-local so parallel tracing threads (e.g.
# pjit compilation workers or test runners) never see each other's rules.
_state = threading.local()


def _stack() -> list:
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


@contextmanager
def use_rules(mesh, rules: Mapping[str, P]):
    """Activate ``rules`` (name -> PartitionSpec) on ``mesh`` for the block."""
    _stack().append((mesh, dict(rules)))
    try:
        yield
    finally:
        _stack().pop()


def current_rules():
    """The active (mesh, rules) pair, or None outside any context."""
    stack = _stack()
    return stack[-1] if stack else None


def _fit(spec: P, shape: tuple, mesh) -> P:
    """Drop rule axes the array cannot honor: absent from the mesh, not
    dividing the dim, or already claimed by an earlier dim of this spec
    (``fit_axes`` is the shared greedy-relaxation rule)."""
    sizes = {a: mesh.shape[a] for a in mesh.axis_names}
    used: set = set()
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = fit_axes(dim, axes, sizes, used)
        used.update(kept)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def shard(x: jax.Array, rule: str) -> jax.Array:
    """Constrain ``x`` to the active named rule; identity with no context."""
    active = current_rules()
    if active is None:
        return x
    mesh, rules = active
    if rule not in rules:
        raise KeyError(
            f"unknown sharding rule {rule!r}; active rules: {sorted(rules)}")
    spec = rules[rule]
    if x.ndim < len(spec):
        # Lower-rank call site (e.g. "act_btf" on (T, F) flattened tokens in
        # the MoE shared-expert path): keep the batch (first) and feature
        # (last) entries and squeeze the middle.
        if x.ndim < 2:
            raise ValueError(
                f"rule {rule!r} spec {spec} cannot apply to shape {x.shape}")
        spec = P(spec[0], *([None] * (x.ndim - 2)), spec[-1])
    elif x.ndim > len(spec):
        raise ValueError(
            f"rule {rule!r} spec {spec} has rank {len(spec)} but array has "
            f"shape {x.shape}")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _fit(spec, x.shape, mesh)))
