"""Mesh partitioners: activation rule tables and FSDP x TP param placement.

All functions return ``PartitionSpec`` trees / tables and only consult
``mesh.axis_names`` / ``mesh.shape`` — no device state — so they work with
real meshes and with symbolic stand-ins (the divisibility tests use a fake
16x16 mesh object with no devices behind it).

Placement policy (DESIGN.md §3):

  * FSDP: matrices shard one non-TP dim over "data" ("model" joins the
    FSDP axes when tensor parallelism is off, making pure-DP runs ZeRO-3
    over the whole slice).
  * TP: Megatron pairing — up/QKV projections column-parallel (output dim
    over "model"), output/down projections row-parallel (input dim over
    "model"), embedding + lm head vocab-parallel, MoE expert weights
    expert-parallel (leading E dim over "model").
  * Head quantum: a fused (D, H*hd) projection is only split over "model"
    when the HEAD COUNT divides the axis — wk/wv with few kv heads stay
    whole rather than splitting inside head_dim (MQA archs replicate k/v).
  * Every assignment is divisibility-checked against the mesh; axes that
    do not fit are dropped (tuple assignments keep their longest fitting
    prefix), so one policy covers all archs from 125M to 398B.
  * ``cluster_dim``: a leading K cluster dim (CroSatFL cluster = pod,
    paper §IV) shards over "pod"; dim 0 is then reserved and no other
    assignment may claim it.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional

import jax
from jax.sharding import PartitionSpec as P


def _sizes(mesh) -> dict[str, int]:
    return {a: mesh.shape[a] for a in mesh.axis_names}


def data_axes(mesh, *, tp: bool = True, cluster_vmapped: bool = False):
    """Mesh axes that carry the batch dimension.

    The "pod" axis joins only when the cluster dim is NOT handled by a
    ``vmap(spmd_axis_name="pod")`` wrapper (which inserts it itself), and
    "model" joins when tensor parallelism is off (pure-DP mode spreads the
    batch over the whole slice)."""
    axes = []
    if "pod" in mesh.axis_names and not cluster_vmapped:
        axes.append("pod")
    axes.append("data")
    if not tp and "model" in mesh.axis_names:
        axes.append("model")
    return tuple(axes)


# ---------------------------------------------------------------------------
# Activation rules (the vocabulary consumed by models/ via dist.ctx.shard)
# ---------------------------------------------------------------------------

def activation_rules(mesh, *, cluster_vmapped: bool = False,
                     tp: bool = True) -> dict[str, P]:
    """Rule table for one placement of the model-side ``shard`` call sites.

    ``cluster_vmapped``: the K-cluster train step vmaps over "pod", so the
    per-cluster rules must not mention it. ``tp=False`` folds "model" into
    the batch axes and drops all feature-dim constraints."""
    b = data_axes(mesh, tp=tp, cluster_vmapped=cluster_vmapped)
    m = "model" if tp else None
    return {
        "act_btd":  P(b, None, m),          # (B, S, d_model)
        "act_bthd": P(b, None, m, None),    # (B, S, H, head_dim)
        "act_btf":  P(b, None, m),          # (B, S, d_ff)
        "moe_ecd":  P(m, None, None),       # (E, C, d_model) flat dispatch
        "moe_ecf":  P(m, None, None),       # (E, C, d_ff)
        "moe_gtd":  P(b, None, None),       # (G, T/G, d_model) grouped tokens
        "moe_gecd": P(b, m, None, None),    # (G, E, C, d_model)
        "moe_gecf": P(b, m, None, None),    # (G, E, C, d_ff)
    }


# ---------------------------------------------------------------------------
# Assignment engine
# ---------------------------------------------------------------------------

def fit_axes(dim: int, axes, sizes: Mapping[str, int], used=()) -> tuple:
    """Longest prefix of ``axes`` that can split ``dim``: each axis must
    exist in ``sizes``, not already be ``used``, and the running axis
    product must divide ``dim``. The single greedy-relaxation rule shared
    by the partitioners here and by ``ctx.shard``."""
    kept, prod = [], 1
    for a in axes:
        n = sizes.get(a)
        if a in used or n is None or dim % (prod * n):
            break
        kept.append(a)
        prod *= n
    return tuple(kept)


class _Assigner:
    """Builds one PartitionSpec, enforcing axis uniqueness, divisibility,
    and the reserved cluster dim."""

    def __init__(self, shape, sizes: dict[str, int], reserved: int = 0):
        self.shape = shape
        self.sizes = sizes
        self.entries: list[Any] = [None] * len(shape)
        self.used: set[str] = set()
        self.reserved = reserved

    def put(self, dim: int, axes) -> bool:
        """Assign ``axes`` (greedy prefix that fits) to ``dim``; negative
        dims count from the end. Returns True if anything was placed."""
        if axes is None:
            return False
        axes = axes if isinstance(axes, (tuple, list)) else (axes,)
        d = dim if dim >= 0 else len(self.shape) + dim
        if d < self.reserved or d >= len(self.shape) or self.entries[d] is not None:
            return False
        kept = fit_axes(self.shape[d], axes, self.sizes, self.used)
        if not kept:
            return False
        self.entries[d] = kept if len(kept) > 1 else kept[0]
        self.used.update(kept)
        return True

    def spec(self) -> P:
        return P(*self.entries)


def _path_keys(path) -> tuple[str, ...]:
    keys = []
    for e in path:
        if hasattr(e, "key"):
            keys.append(str(e.key))
    return tuple(keys)


# ---------------------------------------------------------------------------
# Parameter placement
# ---------------------------------------------------------------------------

# Column-parallel (TP on output dim -1, FSDP on input dim -2). The value is
# the cfg attribute naming the head quantum guarding the split, or None.
_COL = {
    "wq": "num_heads", "wk": "num_kv_heads", "wv": "num_kv_heads",
    "w_uq": "num_heads", "w_uk": "num_heads", "w_uv": "num_heads",
    "w_q": "num_heads", "w_k": "num_heads", "w_v": "num_heads",
    "lm_head": None, "router": None,
    "w_up": None, "w_gate": None, "mlp_up": None, "mlp_gate": None,
    "in_proj": None, "x_proj": None, "dt_proj": None,
    "w_dq": None, "w_dkv": None, "w_kr": None, "w_x": None,
    "w_i": None, "w_f": None,
}

# Row-parallel (TP on input dim -2, FSDP on output dim -1).
_ROW = {
    "wo": "num_heads", "w_down": None, "out_proj": None, "mlp_down": None,
}

_EXPERT_NAMES = ("w_gate", "w_up", "w_down")


def _unit_ok(cfg, attr: Optional[str], n: int) -> bool:
    if attr is None or cfg is None:
        return True
    unit = getattr(cfg, attr, 0)
    return bool(unit) and unit % n == 0


def param_specs(tree, mesh, *, cfg=None, cluster_dim: bool = False,
                fsdp: bool = True, tp: bool = True):
    """PartitionSpec tree mirroring ``tree`` (arrays or ShapeDtypeStructs).

    ``cluster_dim``: every leaf carries a leading K cluster dim sharded
    over "pod". ``fsdp=False`` keeps params replicated over the data axes;
    ``tp=False`` drops all "model" weight splits (the axis then joins the
    FSDP axes instead)."""
    sizes = _sizes(mesh)
    model_n = sizes.get("model", 1)
    tp_axis = "model" if (tp and "model" in sizes) else None
    fsdp_axes: Optional[tuple] = ("data",) if fsdp else None
    if fsdp and not tp and "model" in sizes:
        fsdp_axes = ("data", "model")

    def one(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        asg = _Assigner(leaf.shape, sizes)
        if cluster_dim:
            asg.put(0, "pod")
            asg.reserved = 1

        is_expert = (name in _EXPERT_NAMES and "moe" in keys
                     and "shared" not in keys)
        if is_expert:
            # (E, d_in, d_out): expert-parallel over "model", FSDP on the
            # larger of the two per-expert dims.
            asg.put(-3, tp_axis)
            big, small = (-2, -1) if leaf.shape[-2] >= leaf.shape[-1] else (-1, -2)
            asg.put(big, fsdp_axes) or asg.put(small, fsdp_axes)
        elif name == "embed":
            # (V, D) vocab-parallel; head matmuls reduce over the model axis
            asg.put(-2, tp_axis)
            asg.put(-1, fsdp_axes)
        elif name in _COL and len(leaf.shape) >= 2:
            if _unit_ok(cfg, _COL[name], model_n):
                asg.put(-1, tp_axis)
            asg.put(-2, fsdp_axes)
        elif name in _ROW and len(leaf.shape) >= 2:
            if _unit_ok(cfg, _ROW[name], model_n):
                asg.put(-2, tp_axis)
            asg.put(-1, fsdp_axes)
        elif len(leaf.shape) - (1 if cluster_dim else 0) >= 2:
            # unknown matrices (conv filters, positional tables, SSM state
            # matrices): FSDP the largest dim that fits
            dims = sorted(range(len(leaf.shape)), key=lambda d: -leaf.shape[d])
            for d in dims:
                if asg.put(d, fsdp_axes):
                    break
        # 1D leaves (norm scales, biases) stay replicated
        return asg.spec()

    return jax.tree_util.tree_map_with_path(one, tree)


# ---------------------------------------------------------------------------
# Batch / cache placement
# ---------------------------------------------------------------------------

def batch_specs(batch, mesh, *, cluster_dim: bool = False, tp: bool = True):
    """Input-batch PartitionSpecs.

    The batch dim shards over ``data_axes``; with ``cluster_dim`` the
    leading K dim shards over "pod" and the in-cluster batch over "data".
    ``position_ids`` carries a leading (3,) M-RoPE dim before the batch."""
    sizes = _sizes(mesh)
    baxes = data_axes(mesh, tp=tp, cluster_vmapped=cluster_dim)

    def one(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        asg = _Assigner(leaf.shape, sizes)
        bdim = (1 if name == "position_ids" else 0) + (1 if cluster_dim else 0)
        if cluster_dim:
            asg.put(0, "pod")
        asg.put(bdim, baxes)
        return asg.spec()

    return jax.tree_util.tree_map_with_path(one, batch)


# Cache leaves with a sequence dim, by name: (batch dim, seq dim, head dim)
# indexed from the END of the shape so leading layer-stack dims don't matter.
_SEQ_CACHES = {
    "k": (-4, -3, -2), "v": (-4, -3, -2),       # (..., B, S, Hkv, hd)
    "xk": (-4, -3, -2), "xv": (-4, -3, -2),     # cross-attn context k/v
    "c_kv": (-3, -2, None), "k_rope": (-3, -2, None),   # MLA latent cache
}


def cache_specs_sharding(cache, mesh, *, tp: bool = True):
    """Decode-cache PartitionSpecs.

    KV caches shard the batch dim over "data" when it fits; long-context
    small-batch caches (the 500k-token cell) fall back to SEQUENCE sharding
    over "data" so a single sequence's cache spreads across the slice. KV
    heads additionally shard over "model" under TP. Recurrent states (SSM /
    xLSTM) shard their batch dim only."""
    sizes = _sizes(mesh)
    tp_axis = "model" if (tp and "model" in sizes) else None

    def one(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        asg = _Assigner(leaf.shape, sizes)
        if name in _SEQ_CACHES and len(leaf.shape) >= 3:
            bdim, sdim, hdim = _SEQ_CACHES[name]
            asg.put(bdim, "data") or asg.put(sdim, "data")
            if hdim is not None:
                asg.put(hdim, tp_axis)
        else:
            # recurrent state: batch dim follows any layer-stack dim
            bdim = 1 if "periods" in keys else 0
            asg.put(bdim, "data")
        return asg.spec()

    return jax.tree_util.tree_map_with_path(one, cache)
