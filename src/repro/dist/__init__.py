"""repro.dist — the distribution layer: named sharding rules + partitioners.

Models never mention meshes. Every layer annotates its activations with
``shard(x, "<rule name>")`` (see :mod:`repro.dist.ctx`); a launcher opts a
computation into a placement by wrapping it in ``use_rules(mesh, rules)``
with a rule table built by :func:`repro.dist.sharding.activation_rules`.
Outside any active context ``shard`` is an identity, so the same model code
runs on a laptop CPU, a test mesh, or the 512-device production meshes.

Rule-name vocabulary (the complete set emitted by ``models/``):

  ============  =====================  =========================================
  name          activation shape       placement (tp=True)
  ============  =====================  =========================================
  ``act_btd``   (B, S, d_model)        batch over data axes, d_model over model
  ``act_bthd``  (B, S, H, head_dim)    batch over data axes, heads over model
  ``act_btf``   (B, S, d_ff)           batch over data axes, d_ff over model
  ``moe_ecd``   (E, C, d_model)        experts over model (flat dispatch buf)
  ``moe_ecf``   (E, C, d_ff)           experts over model (flat expert hidden)
  ``moe_gtd``   (G, T/G, d_model)      groups over data axes (grouped tokens)
  ``moe_gecd``  (G, E, C, d_model)     groups over data, experts over model
  ``moe_gecf``  (G, E, C, d_ff)        groups over data, experts over model
  ============  =====================  =========================================

Cluster/pod-axis mapping (paper §IV): CroSatFL trains K satellite clusters
in parallel and mixes them with a random-k cross-aggregation matrix. On the
``(pod, data, model)`` production mesh the correspondence is

  * cluster k        = pod k. Cluster-local state carries a leading K dim
    sharded ``P("pod")``; the clustered train step vmaps the per-cluster
    computation with ``spmd_axis_name="pod"``, so ``activation_rules(...,
    cluster_vmapped=True)`` must NOT mention "pod" — vmap inserts it.
  * intra-cluster aggregation (Eq. 26, with Skip-One as zero-weighted
    client shards) = the data-axis gradient all-reduce inside one pod.
  * random-k cross-aggregation (Eq. 37) = the (K, K) mixing einsum — the
    only cross-pod (DCN) collective.

Partitioners in :mod:`repro.dist.sharding`: ``param_specs`` (FSDP x TP with
the head-quantum rule), ``batch_specs``, ``cache_specs_sharding``
(sequence-sharded long-context KV), and ``data_axes``.
"""
from repro.dist.ctx import current_rules, shard, use_rules
from repro.dist.sharding import (activation_rules, batch_specs,
                                 cache_specs_sharding, data_axes, param_specs)

__all__ = [
    "activation_rules", "batch_specs", "cache_specs_sharding",
    "current_rules", "data_axes", "param_specs", "shard", "use_rules",
]
