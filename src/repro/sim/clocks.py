"""Virtual clocks for the event kernel (DESIGN.md §11).

A ``ClockSet`` is a bag of named monotone clocks on the simulated
timeline: one per training cluster (integer keys), one per ground
station track (string keys like ``"gs"``), plus whatever a driver
registers. Clocks only move forward — ``advance_to`` clamps against the
current value, so an out-of-order event can never rewind a timeline —
and export/import as a flat JSON-able dict for checkpointing.
"""
from __future__ import annotations

from typing import Iterable, Optional, Union

Key = Union[int, str]


class ClockSet:
    def __init__(self):
        self._t: dict[Key, float] = {}

    def __contains__(self, name: Key) -> bool:
        return name in self._t

    def __getitem__(self, name: Key) -> float:
        return self._t[name]

    def __len__(self) -> int:
        return len(self._t)

    def names(self) -> list[Key]:
        return list(self._t)

    def init(self, name: Key, t: float) -> None:
        """Register a clock at t — no-op if it already exists (a resumed
        session's restored clocks must not be clobbered by bind())."""
        self._t.setdefault(name, float(t))

    def reset(self, t: Optional[float] = None) -> None:
        """Drop every clock (t=None) or rewind all of them to t — only
        legal at session start, before any event has been scheduled."""
        if t is None:
            self._t.clear()
        else:
            self._t = {k: float(t) for k in self._t}

    def advance_to(self, name: Key, t: float) -> float:
        """Move ``name`` forward to t (monotone: never rewinds)."""
        cur = self._t.get(name, float("-inf"))
        self._t[name] = max(cur, float(t))
        return self._t[name]

    def max(self, names: Optional[Iterable[Key]] = None) -> float:
        keys = list(self._t if names is None else names)
        return max(self._t[k] for k in keys) if keys else 0.0

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        # JSON object keys are strings; load_state_dict undoes this.
        return {str(k): float(v) for k, v in self._t.items()}

    def load_state_dict(self, state: dict) -> None:
        self._t = {(int(k) if str(k).lstrip("-").isdigit() else str(k)):
                   float(v) for k, v in state.items()}
