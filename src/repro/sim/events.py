"""Deterministic discrete-event simulation kernel (DESIGN.md §11).

A constellation session is a stream of *events* on the simulated clock:
contact windows open and close, clusters finish local training, LISL
transfers complete, stragglers hit their deadline, merges commit. The
kernel is a heap-ordered queue of such events with a total, reproducible
order:

    (time, kind priority, seeded tie-break, sequence number)

* **time** — absolute sim seconds (the same clock the ``EnergyLedger``
  advances).
* **kind priority** — simultaneous events resolve in physical order:
  a contact that closes at t is gone before one that opens at t; training
  that finishes at t precedes the transfer/merge it triggers.
* **seeded tie-break** — events equal in (time, priority) order by a
  float drawn from the kernel's own ``np.random.Generator`` at push time,
  so simultaneous-arrival order (async merge ranks, co-timed contacts)
  is a reproducible function of the seed rather than of heap internals.
* **sequence number** — final fallback; also makes the heap entries
  totally ordered so ``Event`` never needs comparison methods.

The kernel touches neither the engine's host RNG nor its JAX key stream
(its Generator is private), so attaching it to a session cannot perturb
selection jitter, cross-agg sampling, or model weights — the basis of
the sync-replay bit-parity argument in driver.py.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# Event taxonomy (DESIGN.md §11). String values double as the ``etype``
# field of the ``sim_event`` trace record (repro.obs.trace).
CONTACT_CLOSE = "contact_close"
CONTACT_OPEN = "contact_open"
TRAIN_DONE = "train_done"
STRAGGLER_TIMEOUT = "straggler_timeout"
TRANSFER_DONE = "transfer_done"
MERGE_COMMIT = "merge_commit"

# Fault taxonomy (DESIGN.md §13, repro.faults): injected by a
# ``FaultInjector``'s private kernel, never by the physical drivers.
LINK_UP = "link_up"
SAT_REBOOT = "sat_reboot"
LINK_DOWN = "link_down"
SAT_CRASH = "sat_crash"
MASTER_FAIL = "master_fail"
PAYLOAD_CORRUPT = "payload_corrupt"
PAYLOAD_LOSS = "payload_loss"
CLOCK_DRIFT = "clock_drift"
SILENT_CORRUPT = "silent_corrupt"

# Physical resolution order for co-timed events (smaller pops first).
# Fault kinds extend the total order at negative priorities so the
# environment's state is settled before any physical event at the same
# instant resolves against it — and recoveries resolve before faults, so
# a reboot+crash (or up+down) glitch co-timed at t leaves the element
# DOWN, never a lost fault. Existing kinds keep their exact values: the
# golden event order of the physical drivers is untouched.
PRIORITY = {
    SILENT_CORRUPT: -9,
    LINK_UP: -8,
    SAT_REBOOT: -7,
    LINK_DOWN: -6,
    SAT_CRASH: -5,
    MASTER_FAIL: -4,
    PAYLOAD_CORRUPT: -3,
    PAYLOAD_LOSS: -2,
    CLOCK_DRIFT: -1,
    CONTACT_CLOSE: 0,
    CONTACT_OPEN: 1,
    TRAIN_DONE: 2,
    STRAGGLER_TIMEOUT: 3,
    TRANSFER_DONE: 4,
    MERGE_COMMIT: 5,
}


@dataclass(frozen=True)
class Event:
    """One scheduled occurrence on the sim clock.

    ``cluster`` is a training-cluster index, ``sat`` a raw satellite id
    (constellation numbering) — either may be None. ``payload`` carries
    kind-specific floats (e.g. the raw cluster barrier a TRAIN_DONE was
    scheduled from, so downstream consumers can recover the exact float
    that entered the ledger instead of re-deriving it from absolute
    times, which would not be bit-stable).
    """
    t: float
    kind: str
    cluster: Optional[int] = None
    sat: Optional[int] = None
    seq: int = 0
    payload: dict = field(default_factory=dict)


class EventQueue:
    """Heap-ordered event queue with seeded, bit-reproducible ordering."""

    def __init__(self, seed: int = 0):
        self._seed = seed
        self._heap: list = []
        self._seq = 0
        self.rng = np.random.default_rng(seed)

    def __len__(self) -> int:
        return len(self._heap)

    def reset(self, seed: Optional[int] = None) -> None:
        """Drop all pending events and re-seed the tie-break stream —
        a reused kernel starting a fresh session must replay the exact
        same order as a brand-new one."""
        self._heap.clear()
        self._seq = 0
        self.rng = np.random.default_rng(self._seed if seed is None
                                         else seed)

    def push(self, t: float, kind: str, cluster: Optional[int] = None,
             sat: Optional[int] = None, **payload) -> Event:
        ev = Event(t=float(t), kind=kind,
                   cluster=None if cluster is None else int(cluster),
                   sat=None if sat is None else int(sat),
                   seq=self._seq, payload=payload)
        tie = float(self.rng.random())
        heapq.heappush(self._heap,
                       (ev.t, PRIORITY.get(kind, 9), tie, ev.seq, ev))
        self._seq += 1
        return ev

    def peek_t(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[-1]

    def pop_until(self, t: float) -> list[Event]:
        """Pop every event with time <= t (inclusive), in kernel order."""
        out = []
        while self._heap and self._heap[0][0] <= t:
            out.append(self.pop())
        return out

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Tie-break RNG state + sequence counter, JSON-serializable.

        The physical drivers drain the queue to the round boundary before
        the engine snapshots pacing state, so their kernels checkpoint
        with an empty heap and keep the exact pre-existing schema. Fault
        kernels (repro.faults) legitimately carry FUTURE events (an
        outage end, a scheduled crash) across round boundaries — a
        non-empty heap is exported in full under ``"events"`` (sorted in
        kernel pop order, tie-breaks included) so a resumed campaign
        replays the uninterrupted one bit-for-bit."""
        sd = {"seq": int(self._seq),
              "rng": self.rng.bit_generator.state,
              "pending": len(self._heap)}
        if self._heap:
            sd["events"] = [
                [t, prio, tie, seq,
                 {"kind": ev.kind, "cluster": ev.cluster, "sat": ev.sat,
                  "payload": ev.payload}]
                for t, prio, tie, seq, ev in
                sorted(self._heap, key=lambda e: e[:4])]
        return sd

    def load_state_dict(self, state: dict) -> None:
        """Restore a ``state_dict()`` snapshot. Validates the schema and
        every pending event's kind up front — an unknown kind fails HERE
        with a clear error, not rounds later as a pop-time surprise."""
        if not isinstance(state, dict):
            raise ValueError("EventQueue.load_state_dict: state must be a "
                             f"dict, got {type(state).__name__}")
        missing = [k for k in ("seq", "rng") if k not in state]
        if missing:
            raise ValueError("EventQueue.load_state_dict: state missing "
                             f"required keys {missing}")
        entries = []
        for i, entry in enumerate(state.get("events") or []):
            if not (isinstance(entry, (list, tuple)) and len(entry) == 5
                    and isinstance(entry[4], dict)):
                raise ValueError("EventQueue.load_state_dict: malformed "
                                 f"pending-event entry #{i}: {entry!r}")
            t, prio, tie, seq, ev = entry
            kind = ev.get("kind")
            if kind not in PRIORITY:
                raise ValueError(
                    f"EventQueue.load_state_dict: unknown event kind "
                    f"{kind!r} in pending event #{i}; known kinds: "
                    f"{sorted(PRIORITY)}")
            entries.append((float(t), int(prio), float(tie), int(seq),
                            Event(t=float(t), kind=kind,
                                  cluster=ev.get("cluster"),
                                  sat=ev.get("sat"), seq=int(seq),
                                  payload=dict(ev.get("payload") or {}))))
        self._heap.clear()
        self._heap.extend(entries)
        heapq.heapify(self._heap)
        self._seq = int(state["seq"])
        self.rng = np.random.default_rng(self._seed)
        self.rng.bit_generator.state = state["rng"]
