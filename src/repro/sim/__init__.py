"""repro.sim — deterministic discrete-event constellation simulation
(DESIGN.md §11).

* ``events``  — the kernel: heap-ordered ``EventQueue`` with a seeded,
  bit-reproducible total order and the event taxonomy (contact
  open/close, train done, transfer done, straggler timeout, merge
  commit, plus the fault kinds repro.faults injects: link down/up,
  sat crash/reboot, master fail, payload corrupt/loss, clock drift).
* ``clocks``  — per-cluster / per-GS monotone virtual clocks.
* ``windows`` — ``WindowTable`` contact windows streamed as events.
* ``driver``  — pacing policies that run the ``RoundEngine`` on the
  kernel: ``EventDrivenPacing`` (replay any round-granular policy;
  sync replay is golden-ledger bit-exact) and ``EventAsyncPacing``
  (true per-cluster clocks, LISL-availability merge commits,
  sim-time staleness).
"""
from repro.sim.clocks import ClockSet
from repro.sim.driver import EventAsyncPacing, EventDrivenPacing
from repro.sim.events import (CLOCK_DRIFT, CONTACT_CLOSE, CONTACT_OPEN,
                              LINK_DOWN, LINK_UP, MASTER_FAIL, MERGE_COMMIT,
                              PAYLOAD_CORRUPT, PAYLOAD_LOSS, SAT_CRASH,
                              SAT_REBOOT, STRAGGLER_TIMEOUT, TRAIN_DONE,
                              TRANSFER_DONE, Event, EventQueue)
from repro.sim.windows import WindowEventSource

__all__ = [
    "CLOCK_DRIFT", "CONTACT_CLOSE", "CONTACT_OPEN", "LINK_DOWN", "LINK_UP",
    "MASTER_FAIL", "MERGE_COMMIT", "PAYLOAD_CORRUPT", "PAYLOAD_LOSS",
    "SAT_CRASH", "SAT_REBOOT", "STRAGGLER_TIMEOUT", "TRAIN_DONE",
    "TRANSFER_DONE", "ClockSet", "Event", "EventAsyncPacing",
    "EventDrivenPacing", "EventQueue", "WindowEventSource",
]
