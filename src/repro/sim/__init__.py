"""repro.sim — deterministic discrete-event constellation simulation
(DESIGN.md §11).

* ``events``  — the kernel: heap-ordered ``EventQueue`` with a seeded,
  bit-reproducible total order and the event taxonomy (contact
  open/close, train done, transfer done, straggler timeout, merge
  commit).
* ``clocks``  — per-cluster / per-GS monotone virtual clocks.
* ``windows`` — ``WindowTable`` contact windows streamed as events.
* ``driver``  — pacing policies that run the ``RoundEngine`` on the
  kernel: ``EventDrivenPacing`` (replay any round-granular policy;
  sync replay is golden-ledger bit-exact) and ``EventAsyncPacing``
  (true per-cluster clocks, LISL-availability merge commits,
  sim-time staleness).
"""
from repro.sim.clocks import ClockSet
from repro.sim.driver import EventAsyncPacing, EventDrivenPacing
from repro.sim.events import (CONTACT_CLOSE, CONTACT_OPEN, MERGE_COMMIT,
                              STRAGGLER_TIMEOUT, TRAIN_DONE, TRANSFER_DONE,
                              Event, EventQueue)
from repro.sim.windows import WindowEventSource

__all__ = [
    "CONTACT_CLOSE", "CONTACT_OPEN", "MERGE_COMMIT", "STRAGGLER_TIMEOUT",
    "TRAIN_DONE", "TRANSFER_DONE", "ClockSet", "Event", "EventAsyncPacing",
    "EventDrivenPacing", "EventQueue", "WindowEventSource",
]
