"""Event-driven pacing: the RoundEngine's policies on the kernel clock
(DESIGN.md §11).

Two pacing policies built on ``repro.sim.events``:

``EventDrivenPacing``
    Wraps any round-granular pacing policy (Sync / SemiSync / Async) and
    REPLAYS it through the event kernel: every cluster completion is
    scheduled as a TRAIN_DONE event at (round start + barrier), straggler
    overruns become STRAGGLER_TIMEOUT events at the inner deadline, the
    round close becomes a MERGE_COMMIT, and GS contact windows stream
    from the ``WindowTable`` as CONTACT_OPEN/CLOSE. The kernel orders the
    events; per-cluster and GS virtual clocks advance from the popped
    stream; every pop emits through ``EngineObserver.sim_event``.

    Bit-parity argument (pinned in tests/test_sim_events.py): all
    accounting stays in the wrapped policy — the kernel never touches the
    ledger, the engine's host RNG, or its JAX key stream (the tie-break
    generator is the kernel's own). TRAIN_DONE events carry the RAW
    barrier float as payload, so for a ``SyncPacing`` inner the replayed
    round advance is ``max`` over exactly the floats the lock-step loop
    would have maxed — NOT a difference of absolute event times, which
    would not be bit-stable — and the golden ``EnergyLedger`` reproduces
    bit-for-bit.

``EventAsyncPacing``
    True per-cluster clocks. Each cluster runs on its own timeline:
    clock(kc) advances by that cluster's realized barrier, the merge for
    a finished cluster fires at the next LISL availability epoch
    (``env.next_master_contact``, 1-minute topology granularity — not a
    mean-cycle estimate), and the commit wait is charged to the ledger as
    ``merge_window`` waiting. Staleness is measured in sim SECONDS
    (commit time minus the cluster's previous commit) and discounted by
    the shared ``weights_from_staleness`` rule with tau = this
    generation's mean cycle; commit arrival order (kernel pop order,
    seeded tie-breaks) is reported as the merge rank. The global wall
    advances to the latest commit — max over per-cluster timelines, which
    over a session is ≤ the sum of per-round maxima the sync barrier
    pays. Cross-cluster mixing time (charged globally by the engine)
    re-enters every timeline at the next ``begin_round`` since all
    clusters take part in the exchange.

    ``geom_transfer=True`` (the "CroSatFL-EventAsyncGeo" preset)
    additionally staggers each TRANSFER_DONE by the model's actual
    transfer duration over the shortest master-to-master LISL at the
    availability epoch — ``model_bits / lisl_rate`` serialization plus
    detoured ``WalkerDelta.pair_distance`` propagation — so commits (and
    staleness, ranks, the wall horizon) spread by link geometry instead
    of landing at the instant the link opens. The duration shifts the
    commit time only; the ledger's comm accounting stays with the
    engine's mixing policy (no double charge).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.engine.pacing import (SyncPacing, _bcast, _charge_train,
                                    _combine, weights_from_staleness)
from repro.fl.robust import apply_robustness
from repro.sim.clocks import ClockSet
from repro.sim.events import (CONTACT_CLOSE, CONTACT_OPEN, MERGE_COMMIT,
                              STRAGGLER_TIMEOUT, TRAIN_DONE, TRANSFER_DONE,
                              EventQueue)
from repro.sim.windows import WindowEventSource


def _make_contact_source(ctx, state) -> Optional[WindowEventSource]:
    """GS contact streaming is observability (events -> trace), so it is
    built only when an observer is attached AND the env exposes the
    window table + client->satellite ids; toy unit-test envs get None."""
    if ctx.obs is None or getattr(state, "masters", None) is None:
        return None
    masters = np.asarray(state.masters, int)
    table = getattr(ctx.env, "window_table", None)
    sat_ids = getattr(ctx.env, "sat_ids", None)
    if table is None or sat_ids is None or masters.size == 0:
        return None
    sats = [int(sat_ids[m]) for m in masters]
    cluster_of = {int(sat_ids[m]): kc for kc, m in enumerate(masters)}
    src = WindowEventSource(table, sats, cluster_of)
    src.start(float(ctx.ledger.wall_clock_s))
    return src


class EventDrivenPacing:
    """Replay a round-granular pacing policy through the event kernel."""

    def __init__(self, inner=None, seed: int = 0):
        self.inner = inner if inner is not None else SyncPacing()
        self.kernel = EventQueue(seed)
        self.clocks = ClockSet()
        self._source: Optional[WindowEventSource] = None
        self._ctx = None
        self._t0 = 0.0
        self._round = 0

    # -- engine hooks ---------------------------------------------------------
    def bind(self, ctx, plan, state) -> None:
        """Called once per ``run()`` with the final (fresh or resumed)
        state: seed the clocks at the current wall and attach the contact
        source. A fresh session resets the kernel so reruns on a reused
        engine replay the exact same tie-break stream."""
        self._ctx = ctx
        if state.round_idx == 0:
            self.kernel.reset()
            self.clocks.reset()
        wall = float(ctx.ledger.wall_clock_s)
        for kc in range(plan.n_clusters):
            self.clocks.init(kc, wall)
        self.clocks.init("gs", wall)
        self._source = _make_contact_source(ctx, state)

    def begin_round(self, ctx, round_idx: int) -> None:
        self._ctx, self._round = ctx, round_idx
        self._t0 = float(ctx.ledger.wall_clock_s)
        self.inner.begin_round(ctx, round_idx)

    def account_cluster(self, ctx, sel, kc: int) -> float:
        barrier = self.inner.account_cluster(ctx, sel, kc)
        self.kernel.push(self._t0 + barrier, TRAIN_DONE, cluster=kc,
                         barrier=barrier, round=self._round)
        return barrier

    def merge(self, ctx, model, state, new_models, sels, round_idx):
        return self.inner.merge(ctx, model, state, new_models, sels,
                                round_idx)

    def merge_stacked(self, ctx, model, state, new_stacked, sels,
                      round_idx):
        if hasattr(self.inner, "merge_stacked"):
            return self.inner.merge_stacked(ctx, model, state, new_stacked,
                                            sels, round_idx)
        return self.inner.merge(ctx, model, state,
                                model.unstack(new_stacked, len(sels)),
                                sels, round_idx)

    def advance(self, barriers: list) -> float:
        dt = self.inner.advance(barriers)
        t_close = self._t0 + dt
        # a cluster finishing past the inner policy's round close is a
        # straggler: mark the overrun on the event timeline (SemiSync is
        # the only stock inner that produces these)
        for kc, b in enumerate(barriers):
            if b > dt:
                self.kernel.push(t_close, STRAGGLER_TIMEOUT, cluster=kc,
                                 overrun=b - dt, round=self._round)
        self.kernel.push(t_close, MERGE_COMMIT, round=self._round,
                         barrier=dt)
        if self._source is not None:
            self._source.extend(self.kernel, t_close)
        popped = self.kernel.pop_until(t_close)
        if isinstance(self.inner, SyncPacing):
            # replayed sync advance: max over the RAW barrier payloads of
            # this round's TRAIN_DONE pops — the same floats, the same
            # max, so golden-ledger parity is bit-for-bit by construction
            dt = max((ev.payload["barrier"] for ev in popped
                      if ev.kind == TRAIN_DONE), default=0.0)
        self._drain(popped)
        return dt

    def _drain(self, popped) -> None:
        obs = self._ctx.obs if self._ctx is not None else None
        for ev in popped:
            if ev.kind in (TRAIN_DONE, STRAGGLER_TIMEOUT) \
                    and ev.cluster is not None:
                self.clocks.advance_to(ev.cluster, ev.t)
            elif ev.kind in (CONTACT_OPEN, CONTACT_CLOSE):
                self.clocks.advance_to("gs", ev.t)
            if obs is not None:
                obs.sim_event(ev.kind, ev.t, cluster=ev.cluster,
                              sat=ev.sat, seq=ev.seq, **ev.payload)

    # -- checkpointing --------------------------------------------------------
    def state_dict(self):
        sd = {"kernel": self.kernel.state_dict(),
              "clocks": self.clocks.state_dict()}
        inner_sd = (self.inner.state_dict()
                    if hasattr(self.inner, "state_dict") else None)
        if inner_sd:
            sd.update(inner_sd)     # e.g. SemiSync's {"pending": ...}
        return sd

    def load_state_dict(self, state) -> None:
        state = state or {}
        if "kernel" in state:
            self.kernel.load_state_dict(state["kernel"])
        if "clocks" in state:
            self.clocks.load_state_dict(state["clocks"])
        if hasattr(self.inner, "load_state_dict"):
            self.inner.load_state_dict(state if state.get("pending")
                                       else None)


class EventAsyncPacing:
    """Per-cluster clocks with LISL-availability merge commits."""

    def __init__(self, alpha0: float = 0.6, decay: float = 0.5,
                 tau_s: Optional[float] = None,
                 max_merge_wait_s: float = 1800.0, seed: int = 0,
                 geom_transfer: bool = False):
        if not 0.0 < alpha0 <= 1.0:
            raise ValueError(f"alpha0 must be in (0, 1], got {alpha0}")
        self.alpha0, self.decay, self.tau_s = alpha0, decay, tau_s
        self.max_merge_wait_s = max_merge_wait_s
        self.geom_transfer = geom_transfer
        self.kernel = EventQueue(seed)
        self.clocks = ClockSet()
        self._last_sync: dict[int, float] = {}
        self._wall_end: Optional[float] = None
        self._source: Optional[WindowEventSource] = None
        self._ctx = None
        self._state = None
        self._barriers: list[float] = []
        self._t0 = 0.0
        self._dt = 0.0
        self._round = 0

    # -- engine hooks ---------------------------------------------------------
    def bind(self, ctx, plan, state) -> None:
        self._ctx, self._state = ctx, state
        if state.round_idx == 0:
            self.kernel.reset()
            self.clocks.reset()
            self._last_sync = {}
            self._wall_end = None
        wall = float(ctx.ledger.wall_clock_s)
        for kc in range(plan.n_clusters):
            self.clocks.init(kc, wall)
            self._last_sync.setdefault(kc, wall)
        self._source = _make_contact_source(ctx, state)

    def begin_round(self, ctx, round_idx: int) -> None:
        self._ctx, self._round = ctx, round_idx
        self._t0 = float(ctx.ledger.wall_clock_s)
        if self._wall_end is not None:
            # time the engine spent in the global cross-cluster mix since
            # the last commit horizon: every cluster participates in the
            # exchange, so it elapses on every timeline
            drift = self._t0 - self._wall_end
            if drift > 0.0:
                for name in self.clocks.names():
                    if isinstance(name, int):
                        self.clocks.advance_to(name, self.clocks[name]
                                               + drift)
        self._barriers = []

    def account_cluster(self, ctx, sel, kc: int) -> float:
        # energy + own-cluster barrier idle: identical rule to AsyncPacing
        barrier = _charge_train(ctx, sel, kc)
        self._barriers.append(barrier)
        self.kernel.push(self.clocks[kc] + barrier, TRAIN_DONE, cluster=kc,
                         barrier=barrier, round=self._round)
        return barrier

    def _merge_wait(self, ctx, kc: int, t: float) -> float:
        """Sim-seconds until cluster kc's master has a live routed LISL
        to another master (0.0 for toy envs without the geometry)."""
        env = ctx.env
        masters = getattr(self._state, "masters", None)
        fn = getattr(env, "next_master_contact", None)
        if fn is None or masters is None or len(masters) <= 1:
            return 0.0
        return float(fn(masters, kc, t,
                        max_wait_s=self.max_merge_wait_s))

    def _transfer_duration(self, ctx, kc: int, t: float) -> float:
        """Sim-seconds to push one model over the shortest master-to-master
        LISL at epoch ``t``: serialization (model_bits / lisl_rate) plus
        detoured slant-range propagation from ``WalkerDelta.pair_distance``
        (0.0 for toy envs without the geometry)."""
        env = ctx.env
        masters = getattr(self._state, "masters", None)
        const = getattr(env, "constellation", None)
        sat_ids = getattr(env, "sat_ids", None)
        if const is None or sat_ids is None or masters is None \
                or len(masters) <= 1:
            return 0.0
        si = int(sat_ids[masters[kc]])
        d = min(float(const.pair_distance(si, int(sat_ids[mj]), t))
                for j, mj in enumerate(masters) if j != kc)
        d *= getattr(env, "detour", 1.0)
        lp = env.link_params
        from repro.core.energy import t_lisl
        return float(t_lisl(ctx.cfg.model_bits, lp.lisl_rate, d, lp))

    def _merge_weights(self, ctx) -> tuple[np.ndarray, np.ndarray]:
        """Schedule this generation's transfer/commit events, drain the
        kernel through the commit horizon, and return (alphas, ranks)."""
        K = len(self._barriers)
        if K == 0:
            self._dt = 0.0
            self._wall_end = self._t0
            return np.zeros(0), np.zeros(0, int)
        commits = np.empty(K)
        staleness = np.empty(K)
        for kc in range(K):
            finish = self.clocks[kc] + self._barriers[kc]
            wait = self._merge_wait(ctx, kc, finish)
            if wait > 0.0:
                # observer sees the SAME float the ledger adds
                # (bit-exact mirror reconcile, DESIGN.md §10)
                ctx.ledger.add_wait(wait)
                if ctx.obs is not None:
                    ctx.obs.wait(wait, "merge_window", kc)
            avail = finish + wait
            # transfer payload: extra keys only on the geom path so
            # pre-existing EventAsync traces stay byte-identical
            tp = {"wait": wait}
            if self.geom_transfer:
                dur = self._transfer_duration(ctx, kc, avail)
                tp["transfer_s"] = dur
            else:
                dur = 0.0
            commit = avail + dur
            self.kernel.push(commit, TRANSFER_DONE, cluster=kc, round=self._round,
                             **tp)
            self.kernel.push(commit, MERGE_COMMIT, cluster=kc,
                             staleness=commit - self._last_sync[kc],
                             round=self._round)
            commits[kc] = commit
            staleness[kc] = commit - self._last_sync[kc]
        horizon = float(commits.max())
        if self._source is not None:
            self._source.extend(self.kernel, horizon)
        ranks = np.full(K, -1, int)
        order = 0
        obs = ctx.obs
        for ev in self.kernel.pop_until(horizon):
            if ev.kind == MERGE_COMMIT and ev.cluster is not None:
                ranks[ev.cluster] = order
                order += 1
            elif ev.kind in (CONTACT_OPEN, CONTACT_CLOSE):
                self.clocks.advance_to("gs", ev.t)
            if obs is not None:
                obs.sim_event(ev.kind, ev.t, cluster=ev.cluster,
                              sat=ev.sat, seq=ev.seq, **ev.payload)
        for kc in range(K):
            self.clocks.advance_to(kc, float(commits[kc]))
            self._last_sync[kc] = float(commits[kc])
        tau = (self.tau_s if self.tau_s is not None
               else max(float(staleness.mean()), 1e-9))
        alphas = weights_from_staleness(self.alpha0, self.decay,
                                        staleness, tau)
        self._dt = max(0.0, horizon - self._t0)
        self._wall_end = self._t0 + self._dt
        return alphas, ranks

    def _observe_merge(self, ctx, alphas, ranks) -> None:
        if ctx.obs is None:
            return
        for kc in range(len(ranks)):
            ctx.obs.async_merge(kc, int(ranks[kc]), float(alphas[kc]))

    def merge(self, ctx, model, state, new_models, sels, round_idx):
        new_models = apply_robustness(ctx, model, state, new_models, sels)
        K = len(new_models)
        alphas, ranks = self._merge_weights(ctx)
        self._observe_merge(ctx, alphas, ranks)
        old = model.unstack(state.cluster_models, K)
        merged = [_combine(model.stack([old[kc], new_models[kc]]),
                           float(alphas[kc]))
                  for kc in range(K)]
        return model.stack(merged)

    def merge_stacked(self, ctx, model, state, new_stacked, sels,
                      round_idx):
        new_stacked = apply_robustness(ctx, model, state, new_stacked,
                                       sels)
        alphas, ranks = self._merge_weights(ctx)
        self._observe_merge(ctx, alphas, ranks)
        al = alphas.astype(np.float32)
        return jax.tree.map(
            lambda old, new: ((1.0 - _bcast(al, old)) * old
                              + _bcast(al, new) * new).astype(old.dtype),
            state.cluster_models, new_stacked)

    def advance(self, barriers: list) -> float:
        return self._dt

    # -- checkpointing --------------------------------------------------------
    def state_dict(self):
        return {"kernel": self.kernel.state_dict(),
                "clocks": self.clocks.state_dict(),
                "last_sync": {str(k): float(v)
                              for k, v in self._last_sync.items()},
                "wall_end": self._wall_end}

    def load_state_dict(self, state) -> None:
        state = state or {}
        if not state:
            # None snapshot: clear leftovers from a previous run on this
            # (reused) policy instance; bind() re-seeds the clocks
            self.kernel.reset()
            self.clocks.reset()
            self._last_sync = {}
            self._wall_end = None
            return
        self.kernel.load_state_dict(state["kernel"])
        self.clocks.load_state_dict(state["clocks"])
        self._last_sync = {int(k): float(v)
                           for k, v in state["last_sync"].items()}
        self._wall_end = state.get("wall_end")
