"""GS contact windows as an event source (DESIGN.md §11).

``WindowEventSource`` turns the precomputed ``WindowTable`` visibility
grid (constellation/gs.py) into CONTACT_OPEN / CONTACT_CLOSE events on
the kernel queue. Windows are pulled lazily: each ``extend(queue, t)``
call advances a per-satellite frontier and pushes only the windows that
open before ``t``, so a session never scans visibility past its own
horizon. A window that opens before the frontier but closes after it is
pushed once with its TRUE close time (``WindowTable.windows`` never
truncates closes), and the per-satellite ``last close`` watermark drops
the re-reported ongoing window on the next extension — each physical
pass becomes exactly one open/close event pair.
"""
from __future__ import annotations

from typing import Optional

from repro.sim.events import CONTACT_CLOSE, CONTACT_OPEN, EventQueue


class WindowEventSource:
    def __init__(self, table, sats, cluster_of: Optional[dict] = None):
        self.table = table
        self.sats = [int(s) for s in sats]
        self.cluster_of = {int(k): int(v)
                           for k, v in (cluster_of or {}).items()}
        self._frontier: dict[int, float] = {}
        self._last_close: dict[int, float] = {}

    def start(self, t0: float) -> None:
        self._frontier = {s: float(t0) for s in self.sats}
        self._last_close = {}

    def extend(self, queue: EventQueue, until_t: float) -> int:
        """Push contact events for every tracked satellite whose window
        opens before ``until_t``; returns the number of windows pushed."""
        pushed = 0
        for s in self.sats:
            f = self._frontier.get(s, 0.0)
            if f >= until_t:
                continue
            span = max(until_t - f, self.table.step_s)
            for (t_open, t_close) in self.table.windows(s, f, span):
                if t_open >= until_t:
                    break
                if t_close <= self._last_close.get(s, -1.0):
                    continue        # ongoing window re-reported at f
                kc = self.cluster_of.get(s)
                queue.push(t_open, CONTACT_OPEN, cluster=kc, sat=s,
                           close_t=t_close)
                queue.push(t_close, CONTACT_CLOSE, cluster=kc, sat=s,
                           open_t=t_open)
                self._last_close[s] = t_close
                pushed += 1
            self._frontier[s] = until_t
        return pushed
