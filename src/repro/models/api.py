"""Unified model API over the assigned architecture zoo.

Entry points (all functional, params are pytrees):

    init(cfg, key)                  -> params (real arrays)
    param_specs(cfg)                -> params (ShapeDtypeStructs, no alloc)
    train_loss(params, batch, cfg)  -> scalar CE (+ MoE aux)
    prefill(params, batch, cfg)     -> last-position logits (B, V)
    decode_step(params, batch, cfg) -> (logits, new_cache)
    cache_specs(cfg, batch, max_seq)-> KV/state cache ShapeDtypeStructs
    count_params(cfg)               -> analytic parameter count

Decoder-only archs route through ``models.transformer``; whisper routes
through ``models.encdec``.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import transformer as T


def init(cfg, key: jax.Array):
    if cfg.is_encoder_decoder:
        return ED.encdec_params(cfg, key)
    return T.lm_params(cfg, key)


def param_specs(cfg):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    if cfg.is_encoder_decoder:
        return ED.encdec_params(cfg, None)
    return T.lm_params(cfg, None)


def train_loss(params, batch, cfg, *, remat: bool = True,
               causal_skip: bool = False):
    if cfg.is_encoder_decoder:
        return ED.encdec_loss(params, batch, cfg, remat=remat,
                              causal_skip=causal_skip)
    return T.lm_loss(params, batch, cfg, remat=remat, causal_skip=causal_skip)


def prefill(params, batch, cfg, *, causal_skip: bool = False):
    if cfg.is_encoder_decoder:
        return ED.encdec_prefill(params, batch, cfg, causal_skip=causal_skip)
    return T.lm_prefill(params, batch, cfg, causal_skip=causal_skip)


def decode_step(params, batch, cfg):
    if cfg.is_encoder_decoder:
        return ED.encdec_decode_step(params, batch, cfg)
    return T.lm_decode_step(params, batch, cfg)


def cache_specs(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.dtype
    if cfg.is_encoder_decoder:
        return ED.encdec_cache_specs(cfg, batch, max_seq, dtype)
    return T.build_stack_cache_spec(cfg, batch, max_seq, dtype)


# ---------------------------------------------------------------------------
# Analytic parameter count (exact: sums the spec tree)
# ---------------------------------------------------------------------------

def _tree_size(tree) -> int:
    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


def count_params(cfg, active_only: bool = False) -> int:
    total = _tree_size(param_specs(cfg))
    if not active_only or not cfg.num_experts:
        return total
    # Routed-expert weights: 3 matrices (gate/up/down) of (E, D, F) per MoE
    # layer; only top_k/E of them are active per token.
    n_moe = sum(1 for _, ffn in cfg.layer_kinds if ffn == "moe")
    per_layer_routed = 3 * cfg.num_experts * cfg.d_model * cfg.moe_d_ff
    inactive = n_moe * per_layer_routed * (1 - cfg.moe_top_k / cfg.num_experts)
    return int(total - inactive)


def model_bytes(cfg) -> int:
    """Payload size d (bytes) for the FL communication/energy model."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    return count_params(cfg) * itemsize


# ---------------------------------------------------------------------------
# MODEL_FLOPS for the roofline usefulness ratio (6·N·D tokens rule)
# ---------------------------------------------------------------------------

def model_flops(cfg, tokens: int, kind: str = "train") -> float:
    """6·N·D for training, 2·N·D for inference (N = active params)."""
    n_active = count_params(cfg, active_only=True)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens
