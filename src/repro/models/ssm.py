"""SSM-family mixers: Mamba (selective SSM) and xLSTM (sLSTM / mLSTM).

TPU adaptation notes (DESIGN.md §2): the CUDA selective-scan kernel is
re-thought as a *chunked* scan — `lax.scan` over sequence chunks with an
`associative_scan` inside each chunk — which bounds the materialized
(B, L, d_inner, d_state) tensor to one chunk and keeps the MXU busy on the
within-chunk einsums. mLSTM uses the chunkwise-parallel stabilized form
(quadratic inside a chunk, recurrent matrix-memory across chunks). sLSTM is
inherently sequential (recurrent gate mixing) and runs as a plain scan.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import (F32, ParamFactory, causal_conv1d, _act,
                                 _pick_chunk)

NEG = -1e30


# ===========================================================================
# Mamba
# ===========================================================================

def mamba_dims(cfg):
    di = cfg.mamba_expand * cfg.d_model
    dtr = cfg.mamba_dt_rank or max(1, math.ceil(cfg.d_model / 16))
    return di, dtr, cfg.mamba_d_state, cfg.mamba_d_conv


def mamba_params(pf: ParamFactory, cfg):
    D = cfg.d_model
    di, dtr, ds, dc = mamba_dims(cfg)
    return {
        "in_proj": pf.dense(D, 2 * di),
        "conv_w": pf.dense(dc, di, scale=1.0 / math.sqrt(dc)),
        "conv_b": pf.zeros(di),
        "x_proj": pf.dense(di, dtr + 2 * ds),
        "dt_proj": pf.dense(dtr, di),
        "dt_bias": pf.const(math.log(math.e - 1), di),  # softplus(bias)=1
        "A_log": pf.const(math.log(1.0), di, ds),
        "Dskip": pf.ones(di),
        "out_proj": pf.dense(di, D, scale=1.0 / math.sqrt(di)),
    }


def _ssm_scan_chunk(decay, drive, h0):
    """decay/drive: (B, L, di, ds); h0: (B, di, ds). Returns (h_seq, h_last)."""
    def combine(a, b):
        return (b[0] * a[0], b[0] * a[1] + b[1])

    a_pref, b_pref = lax.associative_scan(combine, (decay, drive), axis=1)
    h_seq = a_pref * h0[:, None] + b_pref
    return h_seq, h_seq[:, -1]


def mamba_fwd(p, x, cfg, *, cache=None, chunk: int = 128):
    """x: (B,S,D). cache: {"conv": (B,dc-1,di), "h": (B,di,ds)} for decode."""
    B, S, D = x.shape
    di, dtr, ds, dc = mamba_dims(cfg)

    xz = x @ p["in_proj"]
    xt, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xt, new_conv = causal_conv1d(xt, p["conv_w"], p["conv_b"], conv_state)
    xt = jax.nn.silu(xt)

    bcd = xt @ p["x_proj"]
    dt_in, B_, C_ = jnp.split(bcd, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_proj"]).astype(F32) +
                         p["dt_bias"].astype(F32))            # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(F32))                       # (di,ds)

    decay_full = jnp.exp(dt[..., None] * A)                    # (B,S,di,ds)
    drive_full = (dt * xt.astype(F32))[..., None] * B_.astype(F32)[:, :, None, :]

    if cache is not None:
        assert S == 1
        h = decay_full[:, 0] * cache["h"].astype(F32) + drive_full[:, 0]
        y = jnp.einsum("bds,bs->bd", h, C_[:, 0].astype(F32))[:, None, :]
        new_cache = {"conv": new_conv, "h": h.astype(cache["h"].dtype)}
    else:
        c = _pick_chunk(S, chunk)
        n = S // c
        dec = decay_full.reshape(B, n, c, di, ds).transpose(1, 0, 2, 3, 4)
        drv = drive_full.reshape(B, n, c, di, ds).transpose(1, 0, 2, 3, 4)
        Cc = C_.reshape(B, n, c, ds).transpose(1, 0, 2, 3).astype(F32)

        def body(h0, xs):
            dch, drh, cch = xs
            h_seq, h_last = _ssm_scan_chunk(dch, drh, h0)
            yc = jnp.einsum("blds,bls->bld", h_seq, cch)
            return h_last, yc

        h0 = jnp.zeros((B, di, ds), F32)
        h_last, ys = lax.scan(body, h0, (dec, drv, Cc))        # ys: (n,B,c,di)
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
        new_cache = None

    y = (y + p["Dskip"].astype(F32) * xt.astype(F32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if cache is not None:
        return out, new_cache
    return out, None


def mamba_cache_spec(cfg, batch: int, dtype):
    di, dtr, ds, dc = mamba_dims(cfg)
    return {"conv": jax.ShapeDtypeStruct((batch, dc - 1, di), dtype),
            "h": jax.ShapeDtypeStruct((batch, di, ds), F32)}


# ===========================================================================
# mLSTM (chunkwise-parallel, stabilized exponential gating)
# ===========================================================================

def mlstm_dims(cfg):
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    return di, H, di // H


def mlstm_params(pf: ParamFactory, cfg):
    D = cfg.d_model
    di, H, dh = mlstm_dims(cfg)
    return {
        "w_up": pf.dense(D, 2 * di),
        "conv_w": pf.dense(cfg.xlstm_conv, di, scale=0.5),
        "conv_b": pf.zeros(di),
        "w_q": pf.dense(di, di),
        "w_k": pf.dense(di, di),
        "w_v": pf.dense(di, di),
        "w_i": pf.dense(di, H, scale=0.02),
        "b_i": pf.zeros(H),
        "w_f": pf.dense(di, H, scale=0.02),
        "b_f": pf.const(3.0, H),       # forget-gate bias: start remembering
        "gn_scale": pf.ones(di),
        "skip": pf.ones(di),
        "w_down": pf.dense(di, D, scale=1.0 / math.sqrt(di)),
    }


def _mlstm_chunk(q, k, v, logi, logf, C0, n0, m0):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,L,dh); logi,logf: (B,H,L); carry C0 (B,H,dh,dh),
    n0 (B,H,dh), m0 (B,H). Returns (h, C1, n1, m1).
    """
    B, H, L, dh = q.shape
    Fcum = jnp.cumsum(logf, axis=-1)                          # (B,H,L)
    # pairwise log weights a[t,j] = Fcum_t - Fcum_j + logi_j  (j <= t)
    a = Fcum[..., :, None] - Fcum[..., None, :] + logi[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    a = jnp.where(tri, a, NEG)
    b = Fcum + m0[..., None]                                  # (B,H,L) carry weight
    m_t = jnp.maximum(a.max(-1), b)                           # (B,H,L)

    dmat = jnp.exp(a - m_t[..., None])                        # (B,H,L,L)
    carry_w = jnp.exp(b - m_t)                                # (B,H,L)

    scale = 1.0 / math.sqrt(dh)
    qk = jnp.einsum("bhld,bhjd->bhlj", q, k) * scale          # (B,H,L,L)
    num = jnp.einsum("bhlj,bhjd->bhld", qk * dmat, v) \
        + carry_w[..., None] * jnp.einsum("bhld,bhde->bhle", q * scale, C0)
    # denominator: n_t . q_t
    nq = jnp.einsum("bhlj,bhjd,bhld->bhl", dmat, k, q) * scale \
        + carry_w * jnp.einsum("bhd,bhld->bhl", n0, q) * scale
    h = num / jnp.maximum(jnp.abs(nq), jnp.exp(-m_t))[..., None]

    # end-of-chunk carries
    m1 = m_t[..., -1]
    wj = jnp.exp(Fcum[..., -1:] - Fcum + logi - m1[..., None])  # (B,H,L)
    C1 = jnp.exp(Fcum[..., -1] + m0 - m1)[..., None, None] * C0 \
        + jnp.einsum("bhl,bhld,bhle->bhde", wj, k, v)
    n1 = jnp.exp(Fcum[..., -1] + m0 - m1)[..., None] * n0 \
        + jnp.einsum("bhl,bhld->bhd", wj, k)
    return h, C1, n1, m1


def mlstm_fwd(p, x, cfg, *, cache=None, chunk: int = 128):
    B, S, D = x.shape
    di, H, dh = mlstm_dims(cfg)

    up = x @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = causal_conv1d(xm, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    def heads(t):  # (B,S,di) -> (B,H,S,dh) fp32
        return t.reshape(B, S, H, dh).transpose(0, 2, 1, 3).astype(F32)

    q, k, v = heads(xc @ p["w_q"]), heads(xc @ p["w_k"]), heads(xm @ p["w_v"])
    logi = (xc @ p["w_i"] + p["b_i"]).astype(F32).transpose(0, 2, 1)   # (B,H,S)
    logf = jax.nn.log_sigmoid((xc @ p["w_f"] + p["b_f"]).astype(F32)).transpose(0, 2, 1)

    if cache is not None:
        assert S == 1
        C0, n0, m0 = cache["C"].astype(F32), cache["n"].astype(F32), cache["m"]
        m1 = jnp.maximum(logf[..., 0] + m0, logi[..., 0])
        fw = jnp.exp(logf[..., 0] + m0 - m1)
        iw = jnp.exp(logi[..., 0] - m1)
        C1 = fw[..., None, None] * C0 + iw[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", k[:, :, 0], v[:, :, 0])
        n1 = fw[..., None] * n0 + iw[..., None] * k[:, :, 0]
        scale = 1.0 / math.sqrt(dh)
        num = jnp.einsum("bhd,bhde->bhe", q[:, :, 0] * scale, C1)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", n1, q[:, :, 0] * scale))
        h = (num / jnp.maximum(den, jnp.exp(-m1))[..., None])[:, :, None, :]
        new_cache = {"conv": new_conv, "C": C1.astype(cache["C"].dtype),
                     "n": n1.astype(cache["n"].dtype), "m": m1}
    else:
        c = _pick_chunk(S, chunk)
        n_chunks = S // c

        def split(t):  # (B,H,S,dh) -> (n,B,H,c,dh)
            return t.reshape(B, H, n_chunks, c, dh).transpose(2, 0, 1, 3, 4)

        def split3(t):  # (B,H,S) -> (n,B,H,c)
            return t.reshape(B, H, n_chunks, c).transpose(2, 0, 1, 3)

        def body(carry, xs):
            C0, n0, m0 = carry
            qc, kc, vc, lic, lfc = xs
            h, C1, n1, m1 = _mlstm_chunk(qc, kc, vc, lic, lfc, C0, n0, m0)
            return (C1, n1, m1), h

        init = (jnp.zeros((B, H, dh, dh), F32), jnp.zeros((B, H, dh), F32),
                jnp.full((B, H), 0.0, F32))
        _, hs = lax.scan(body, init,
                         (split(q), split(k), split(v), split3(logi), split3(logf)))
        h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)
        new_cache = None

    h = h.transpose(0, 2, 1, 3).reshape(B, S, di)
    # per-head group norm
    hf = h.reshape(B, S, H, dh)
    hf = hf * lax.rsqrt((hf ** 2).mean(-1, keepdims=True) + 1e-5)
    h = hf.reshape(B, S, di) * p["gn_scale"].astype(F32)
    h = h.astype(x.dtype) + p["skip"].astype(x.dtype) * xc
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    return out, new_cache


def mlstm_cache_spec(cfg, batch: int, dtype):
    di, H, dh = mlstm_dims(cfg)
    return {"conv": jax.ShapeDtypeStruct((batch, cfg.xlstm_conv - 1, di), dtype),
            "C": jax.ShapeDtypeStruct((batch, H, dh, dh), F32),
            "n": jax.ShapeDtypeStruct((batch, H, dh), F32),
            "m": jax.ShapeDtypeStruct((batch, H), F32)}


# ===========================================================================
# sLSTM (sequential scan, exponential gating with stabilizer)
# ===========================================================================

def slstm_params(pf: ParamFactory, cfg):
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    ff = int(round(4 * D / 3 / 8)) * 8
    return {
        "w_x": pf.dense(D, 4 * D),
        "b_x": pf.zeros(4 * D),
        "r": pf.dense(H, dh, 4, dh, scale=1.0 / math.sqrt(dh)),
        "gn_scale": pf.ones(D),
        "mlp_up": pf.dense(D, ff),
        "mlp_gate": pf.dense(D, ff),
        "mlp_down": pf.dense(ff, D, scale=1.0 / math.sqrt(ff)),
    }


def _slstm_step(p, gx_t, state, H, dh):
    """gx_t: (B,4D) precomputed input gates; state: (c,n,m,h) each (B,D)."""
    c0, n0, m0, h0 = state
    B = gx_t.shape[0]
    D = H * dh
    rec = jnp.einsum("bhd,hdge->bhge", h0.reshape(B, H, dh).astype(F32),
                     p["r"].astype(F32))                       # (B,H,4,dh)
    g = gx_t.astype(F32).reshape(B, 4, H, dh) + rec.transpose(0, 2, 1, 3)
    zt, it, ft, ot = g[:, 0], g[:, 1], g[:, 2], g[:, 3]        # (B,H,dh)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    lf = jax.nn.log_sigmoid(ft)
    m1 = jnp.maximum(lf + m0.reshape(B, H, dh), it)
    fw = jnp.exp(lf + m0.reshape(B, H, dh) - m1)
    iw = jnp.exp(it - m1)
    c1 = fw * c0.reshape(B, H, dh) + iw * zt
    n1 = fw * n0.reshape(B, H, dh) + iw
    h1 = ot * c1 / jnp.maximum(n1, 1e-6)
    flat = lambda t: t.reshape(B, D)
    return (flat(c1), flat(n1), flat(m1), flat(h1))


def slstm_fwd(p, x, cfg, *, cache=None):
    B, S, D = x.shape
    H = cfg.num_heads
    dh = D // H
    gx = x @ p["w_x"] + p["b_x"]                               # (B,S,4D)

    if cache is not None:
        assert S == 1
        state = (cache["c"], cache["n"], cache["m"], cache["h"])
        state = _slstm_step(p, gx[:, 0], state, H, dh)
        h = state[3][:, None, :]
        new_cache = {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}
    else:
        def body(state, gx_t):
            s = _slstm_step(p, gx_t, state, H, dh)
            return s, s[3]

        init = tuple(jnp.zeros((B, D), F32) for _ in range(4))
        _, hs = lax.scan(body, init, gx.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2)                              # (B,S,D)
        new_cache = None

    hf = h.reshape(B, -1, H, dh)
    hf = hf * lax.rsqrt((hf ** 2).mean(-1, keepdims=True) + 1e-5)
    h = (hf.reshape(B, -1, D) * p["gn_scale"].astype(F32)).astype(x.dtype)
    out = (_act(cfg.act)(h @ p["mlp_gate"]) * (h @ p["mlp_up"])) @ p["mlp_down"]
    return out, new_cache


def slstm_cache_spec(cfg, batch: int, dtype):
    D = cfg.d_model
    sd = jax.ShapeDtypeStruct
    return {"c": sd((batch, D), F32), "n": sd((batch, D), F32),
            "m": sd((batch, D), F32), "h": sd((batch, D), F32)}
