"""Decoder-only / hybrid LM assembly from an ArchConfig.

Layers are organized as ``prefix + pattern * num_periods + suffix``. The
periods are executed with a single ``lax.scan`` over stacked parameters
(one scan step = one period, its pattern unrolled inside the body) — this
keeps the lowered HLO compact even for 60–88 layer models, which matters on
the single-core CPU compile host and on real TPU compile times alike.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.ctx import shard
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.layers import ParamFactory

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Per-layer params by kind
# ---------------------------------------------------------------------------

def _layer_params(pf: ParamFactory, cfg, kind):
    mixer, ffn = kind
    p: dict[str, Any] = {"norm1": L.norm_params(pf, cfg.d_model, cfg.norm)}
    if mixer in ("attn", "attn_local", "attn_global"):
        p["attn"] = L.mla_params(pf, cfg) if cfg.attn_type == "mla" \
            else L.attn_params(pf, cfg)
    elif mixer == "mamba":
        p["mamba"] = S.mamba_params(pf, cfg)
    elif mixer == "mlstm":
        p["mlstm"] = S.mlstm_params(pf, cfg)
    elif mixer == "slstm":
        p["slstm"] = S.slstm_params(pf, cfg)
    else:
        raise ValueError(mixer)

    if ffn != "none":
        p["norm2"] = L.norm_params(pf, cfg.d_model, cfg.norm)
        if ffn == "mlp":
            p["mlp"] = L.mlp_params(pf, cfg.d_model, cfg.d_ff, cfg.mlp_gated)
        elif ffn == "moe":
            p["moe"] = L.moe_params(pf, cfg)
        else:
            raise ValueError(ffn)
    if cfg.sandwich_norm:
        p["post_norm1"] = L.norm_params(pf, cfg.d_model, cfg.norm)
        if ffn != "none":
            p["post_norm2"] = L.norm_params(pf, cfg.d_model, cfg.norm)
    return p


def _layer_cache_spec(cfg, kind, batch: int, max_seq: int, dtype):
    mixer, _ = kind
    if mixer in ("attn", "attn_local", "attn_global"):
        if cfg.attn_type == "mla":
            return L.mla_cache_spec(cfg, batch, max_seq, dtype)
        return L.attn_cache_spec(cfg, batch, max_seq, mixer == "attn_local", dtype)
    if mixer == "mamba":
        return S.mamba_cache_spec(cfg, batch, dtype)
    if mixer == "mlstm":
        return S.mlstm_cache_spec(cfg, batch, dtype)
    if mixer == "slstm":
        return S.slstm_cache_spec(cfg, batch, dtype)
    raise ValueError(mixer)


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def _block_fwd(p, x, aux, cfg, kind, *, positions, cache, pos, causal_skip,
               causal=True):
    mixer, ffn = kind
    h = L.apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
    new_cache = None
    if mixer in ("attn", "attn_local", "attn_global"):
        local = mixer == "attn_local"
        if cfg.attn_type == "mla":
            h, new_cache = L.mla_fwd(p["attn"], h, cfg, positions=positions,
                                     cache=cache, pos=pos,
                                     causal_skip=causal_skip)
        else:
            h, new_cache = L.attn_fwd(p["attn"], h, cfg, local=local,
                                      positions=positions, cache=cache,
                                      pos=pos, causal=causal,
                                      causal_skip=causal_skip)
    elif mixer == "mamba":
        h, new_cache = S.mamba_fwd(p["mamba"], h, cfg, cache=cache)
    elif mixer == "mlstm":
        h, new_cache = S.mlstm_fwd(p["mlstm"], h, cfg, cache=cache)
    elif mixer == "slstm":
        h, new_cache = S.slstm_fwd(p["slstm"], h, cfg, cache=cache)
    if cfg.sandwich_norm:
        h = L.apply_norm(p["post_norm1"], h, cfg.norm, cfg.norm_eps)
    x = x + h
    x = shard(x, "act_btd")

    if ffn != "none":
        h = L.apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if ffn == "mlp":
            h = L.mlp_fwd(p["mlp"], h, cfg.act, cfg.mlp_gated)
        else:
            h, moe_aux = L.moe_fwd(p["moe"], h, cfg)
            aux = aux + moe_aux
        if cfg.sandwich_norm:
            h = L.apply_norm(p["post_norm2"], h, cfg.norm, cfg.norm_eps)
        x = x + h
        x = shard(x, "act_btd")
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Stack params / cache
# ---------------------------------------------------------------------------

def build_stack_params(pf: ParamFactory, cfg):
    pattern = cfg.resolved_pattern
    n_per = cfg.resolved_num_periods

    def period_params():
        return {f"l{i}": _layer_params(pf, cfg, k) for i, k in enumerate(pattern)}

    if pf.key is None:
        one = period_params()
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_per,) + s.shape, s.dtype), one)
    else:
        reps = [period_params() for _ in range(n_per)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)

    return {
        "prefix": [_layer_params(pf, cfg, k) for k in cfg.prefix_pattern],
        "periods": stacked,
        "suffix": [_layer_params(pf, cfg, k) for k in cfg.suffix_pattern],
    }


def build_stack_cache_spec(cfg, batch: int, max_seq: int, dtype):
    pattern = cfg.resolved_pattern
    n_per = cfg.resolved_num_periods
    one = {f"l{i}": _layer_cache_spec(cfg, k, batch, max_seq, dtype)
           for i, k in enumerate(pattern)}
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_per,) + s.shape, s.dtype), one)
    return {
        "prefix": [_layer_cache_spec(cfg, k, batch, max_seq, dtype)
                   for k in cfg.prefix_pattern],
        "periods": stacked,
        "suffix": [_layer_cache_spec(cfg, k, batch, max_seq, dtype)
                   for k in cfg.suffix_pattern],
    }


# ---------------------------------------------------------------------------
# Stack forward (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def stack_fwd(params, x, cfg, *, positions, cache=None, pos=None,
              remat: bool = True, causal_skip: bool = False, causal: bool = True):
    """Returns (x, aux, new_cache)."""
    pattern = cfg.resolved_pattern
    aux = jnp.zeros((), F32)
    decode = cache is not None

    new_prefix = []
    for p, kind, c in zip(params["prefix"], cfg.prefix_pattern,
                          cache["prefix"] if decode else [None] * len(cfg.prefix_pattern)):
        x, aux, nc = _block_fwd(p, x, aux, cfg, kind, positions=positions,
                                cache=c, pos=pos, causal_skip=causal_skip,
                                causal=causal)
        new_prefix.append(nc)

    def period_body(carry, xs):
        x, aux = carry
        pparams = xs[0]
        pcache = xs[1] if decode else None
        new_c = {}
        for i, kind in enumerate(pattern):
            c = pcache[f"l{i}"] if decode else None
            x, aux, nc = _block_fwd(pparams[f"l{i}"], x, aux, cfg, kind,
                                    positions=positions, cache=c, pos=pos,
                                    causal_skip=causal_skip, causal=causal)
            new_c[f"l{i}"] = nc if decode else 0.0
        return (x, aux), (new_c if decode else 0.0)

    body = jax.checkpoint(period_body) if (remat and not decode) else period_body
    xs = (params["periods"], cache["periods"]) if decode else (params["periods"],)
    (x, aux), period_out = lax.scan(body, (x, aux), xs)

    new_suffix = []
    for p, kind, c in zip(params["suffix"], cfg.suffix_pattern,
                          cache["suffix"] if decode else [None] * len(cfg.suffix_pattern)):
        x, aux, nc = _block_fwd(p, x, aux, cfg, kind, positions=positions,
                                cache=c, pos=pos, causal_skip=causal_skip,
                                causal=causal)
        new_suffix.append(nc)

    new_cache = ({"prefix": new_prefix, "periods": period_out,
                  "suffix": new_suffix} if decode else None)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def lm_params(cfg, key: Optional[jax.Array]):
    pf = ParamFactory(key, cfg.dtype)
    p: dict[str, Any] = {
        "embed": pf.dense(cfg.vocab_size, cfg.d_model, scale=0.02),
        "final_norm": L.norm_params(pf, cfg.d_model, cfg.norm),
        "stack": build_stack_params(pf, cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = pf.dense(cfg.d_model, cfg.vocab_size)
    return p


def _embed(params, tokens, cfg, patch_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if patch_embeds is not None:
        P = patch_embeds.shape[1]
        h = jnp.concatenate([patch_embeds.astype(h.dtype), h[:, P:]], axis=1)
    return h


def _head_weight(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def lm_loss(params, batch, cfg, *, remat: bool = True, causal_skip: bool = False):
    """Next-token CE over the batch. batch: tokens/labels (+ extras).

    ``batch["weights"]`` (B,), when present, weights each example — the
    Skip-One participation mask at the datacenter layer (a skipped
    client's shard contributes zero and the mean renormalizes)."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    if cfg.rope_variant == "mrope":
        positions = batch["position_ids"]                     # (3,B,S)
    else:
        positions = jnp.arange(Sq)
    h = _embed(params, tokens, cfg, batch.get("patch_embeds"))
    h = shard(h, "act_btd")
    h, aux, _ = stack_fwd(params["stack"], h, cfg, positions=positions,
                          remat=remat, causal_skip=causal_skip)
    h = L.apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    mask = None
    if "weights" in batch:
        mask = jnp.broadcast_to(batch["weights"][:, None].astype(F32), (B, Sq))
    loss = L.chunked_ce_loss(h, _head_weight(params, cfg), batch["labels"],
                             mask=mask)
    return loss + aux


def lm_prefill(params, batch, cfg, *, causal_skip: bool = False):
    """Forward over the prompt; returns last-position logits."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    positions = batch["position_ids"] if cfg.rope_variant == "mrope" \
        else jnp.arange(Sq)
    h = _embed(params, tokens, cfg, batch.get("patch_embeds"))
    h = shard(h, "act_btd")
    h, _, _ = stack_fwd(params["stack"], h, cfg, positions=positions,
                        remat=False, causal_skip=causal_skip)
    h = L.apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    logits = h[:, -1, :] @ _head_weight(params, cfg)
    return logits


def lm_decode_step(params, batch, cfg):
    """One decode step. batch: token (B,1), pos (B,), cache. Returns
    (logits, new_cache)."""
    token, pos, cache = batch["token"], batch["pos"], batch["cache"]
    if cfg.rope_variant == "mrope":
        positions = batch["position_ids"]                     # (3,B,1)
    else:
        positions = pos[:, None]                              # (B,1)
    h = _embed(params, token, cfg)
    h, _, new_cache = stack_fwd(params["stack"], h, cfg, positions=positions,
                                cache=cache, pos=pos, remat=False)
    h = L.apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    logits = h[:, -1, :] @ _head_weight(params, cfg)
    return logits, new_cache
