"""Pure-JAX building blocks for the assigned model zoo.

Everything is functional: params are nested dicts of arrays (or
ShapeDtypeStructs when built for the dry-run). Compute runs in the config
dtype (bf16) with fp32 softmax/norm internals.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.ctx import shard

F32 = jnp.float32
NEG = -1e30  # finite -inf stand-in (keeps online softmax NaN-free)


# ===========================================================================
# Parameter factory: real init (key given) or ShapeDtypeStruct specs (key=None)
# ===========================================================================

class ParamFactory:
    def __init__(self, key: Optional[jax.Array], dtype):
        self.key = key
        self.dtype = dtype

    def _next(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, *shape: int, scale: Optional[float] = None):
        if self.key is None:
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        if scale is None:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(self._next(), tuple(shape), F32) * scale).astype(self.dtype)

    def zeros(self, *shape: int):
        if self.key is None:
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        return jnp.zeros(tuple(shape), self.dtype)

    def ones(self, *shape: int):
        if self.key is None:
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        return jnp.ones(tuple(shape), self.dtype)

    def const(self, value, *shape: int):
        if self.key is None:
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        return jnp.full(tuple(shape), value, self.dtype)


# ===========================================================================
# Norms
# ===========================================================================

def norm_params(pf: ParamFactory, dim: int, kind: str):
    p = {"scale": pf.ones(dim)}
    if kind == "layernorm":
        p["bias"] = pf.zeros(dim)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-5):
    xf = x.astype(F32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
        y = y * p["scale"].astype(F32) + p["bias"].astype(F32)
    else:  # rmsnorm
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * lax.rsqrt(var + eps) * p["scale"].astype(F32)
    return y.astype(x.dtype)


def rms_headnorm(scale, x, eps: float = 1e-5):
    """qk-norm over the head_dim axis (gemma3)."""
    xf = x.astype(F32)
    y = xf * lax.rsqrt((xf ** 2).mean(-1, keepdims=True) + eps) * scale.astype(F32)
    return y.astype(x.dtype)


# ===========================================================================
# RoPE (standard / partial / M-RoPE)
# ===========================================================================

def _rope_freqs(half: int, theta: float):
    return theta ** (-jnp.arange(0, half, dtype=F32) / half)


def rope_angles(positions, head_dim: int, theta: float, pct: float = 1.0,
                mrope_sections: Optional[tuple[int, ...]] = None):
    """positions: (..., S) int or (3, ..., S) for M-RoPE. Returns (cos, sin)
    of shape (..., S, rot_half) where rot_half = int(head_dim*pct)//2."""
    rot = int(head_dim * pct)
    half = rot // 2
    freqs = _rope_freqs(half, theta)
    if mrope_sections is not None:
        # positions: (3, ..., S); each frequency index belongs to one section.
        # Sections are specified for the canonical head_dim and rescaled to
        # the actual rotary half (reduced smoke configs have tiny head dims).
        tot = sum(mrope_sections)
        if tot != half:
            scaled = [max(1, round(s * half / tot)) for s in mrope_sections]
            scaled[-1] += half - sum(scaled)
            mrope_sections = tuple(scaled)
        sec_idx = jnp.concatenate([
            jnp.full((s,), i, jnp.int32) for i, s in enumerate(mrope_sections)
        ])  # (half,)
        ang_all = positions[..., None].astype(F32) * freqs  # (3, ..., S, half)
        ang = jnp.take_along_axis(
            jnp.moveaxis(ang_all, 0, -1),  # (..., S, half, 3)
            sec_idx[(None,) * (ang_all.ndim - 2) + (slice(None), None)], axis=-1,
        )[..., 0]  # (..., S, half)
    else:
        ang = positions[..., None].astype(F32) * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, pct: float = 1.0):
    """x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half)."""
    hd = x.shape[-1]
    rot = 2 * cos.shape[-1]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(F32), 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    if rot < hd:
        out = jnp.concatenate([out, xp.astype(F32)], axis=-1)
    return out.astype(x.dtype)


# ===========================================================================
# Chunked (flash-style) attention — memory-safe at 32k in pure JAX
# ===========================================================================

def _pick_chunk(s: int, target: int = 512) -> int:
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk_q: int = 0, chunk_k: int = 0,
                      scale: Optional[float] = None,
                      causal_skip: bool = False):
    """q: (B,Sq,H,hd)  k,v: (B,Sk,Hkv,hd/vd).  Online-softmax over kv chunks.

    ``causal_skip``: triangular scan that only visits (q,kv) chunk pairs on or
    below the diagonal — the beyond-paper FLOP-saving schedule (§Perf).
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    cq = chunk_q or _pick_chunk(Sq)
    ck = chunk_k or _pick_chunk(Sk)
    nq, nk = Sq // cq, Sk // ck

    qc = q.reshape(B, nq, cq, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)  # (nq,B,Hkv,G,cq,hd)
    kc = k.reshape(B, nk, ck, Hkv, hd).transpose(1, 0, 3, 2, 4)        # (nk,B,Hkv,ck,hd)
    vc = v.reshape(B, nk, ck, Hkv, vd).transpose(1, 0, 3, 2, 4)

    q_pos = jnp.arange(Sq).reshape(nq, cq)
    k_pos = jnp.arange(Sk).reshape(nk, ck)

    def block(qi, kj, q_blk, k_blk, v_blk, m, l, acc):
        # NOTE (§Perf gemma EXP-D/D', both refuted): neither explicit bf16
        # panel dots nor a bf16 p-downcast reduced traffic — XLA fuses the
        # f32 converts into the dots already, and explicit casts ADD copies
        # (+8% / +24% bytes). The f32-upcast form below measured best.
        s = jnp.einsum("bkgqd,bkcd->bkgqc", q_blk.astype(F32),
                       k_blk.astype(F32)) * scale
        qp = q_pos[qi][None, None, None, :, None]
        kp = k_pos[kj][None, None, None, None, :]
        mask = jnp.ones(s.shape, bool)
        if causal:
            mask &= kp <= qp
        if window:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        # NOTE (§Perf gemma EXP-D, refuted): explicitly downcasting p to
        # bf16 before the pv dot ADDED 24% bytes — the cast materializes an
        # unfused panel copy. Keeping p in f32 lets XLA fuse the exp chain
        # straight into the dot operand.
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p, v_blk.astype(F32))
        return m_new, l_new, acc_new

    if causal and causal_skip and nq == nk and cq == ck:
        # triangular schedule: per q block, scan only the qi+1 on/below-
        # diagonal kv blocks (static prefix length per unrolled q block).
        # NOTE (§Perf gemma): the earlier single-scan-over-pairs version
        # threaded the FULL f32 output through the scan carry — at 32k
        # (nq=64) that carry dominated memory traffic. Per-q scans keep
        # only (m, l, acc) live.
        outs = []
        for qi in range(nq):
            def kv_body(carry, kj, qi=qi):
                m, l, acc = carry
                return block(qi, kj, qc[qi], kc[kj], vc[kj], m, l, acc), ()

            init = (jnp.full((B, Hkv, G, cq), NEG, F32),
                    jnp.zeros((B, Hkv, G, cq), F32),
                    jnp.zeros((B, Hkv, G, cq, vd), F32))
            (m, l, acc), _ = lax.scan(kv_body, init, jnp.arange(qi + 1))
            outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
        out = jnp.stack(outs)                      # (nq, B, Hkv, G, cq, vd)
    else:
        def q_body(_, qi):
            def kv_body(carry, kj):
                m, l, acc = carry
                return block(qi, kj, qc[qi], kc[kj], vc[kj], m, l, acc), ()

            init = (jnp.full((B, Hkv, G, cq), NEG, F32),
                    jnp.zeros((B, Hkv, G, cq), F32),
                    jnp.zeros((B, Hkv, G, cq, vd), F32))
            (m, l, acc), _ = lax.scan(kv_body, init, jnp.arange(nk))
            return None, acc / jnp.maximum(l, 1e-30)[..., None]

        _, out = lax.scan(q_body, None, jnp.arange(nq))  # (nq,B,Hkv,G,cq,vd)

    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, vd)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     scale: Optional[float] = None):
    """Single-token attention over a cache.

    q: (B,1,H,hd); caches: (B,Smax,Hkv,hd|vd); pos: (B,) current position.
    With ``window``, the cache is a ring buffer of size Smax=window and slot
    j holds absolute position pos - ((pos - j) mod window).
    """
    B, _, H, hd = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    vd = v_cache.shape[-1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(F32), k_cache.astype(F32)) * scale
    slots = jnp.arange(Smax)
    if window:
        abs_pos = pos[:, None] - jnp.mod(pos[:, None] - slots[None, :], window)
        valid = (abs_pos >= 0) & (abs_pos <= pos[:, None])
    else:
        valid = slots[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(F32))
    return out.reshape(B, 1, H, vd).astype(q.dtype)


# ===========================================================================
# GQA attention layer (shared by dense / vlm / hybrid / encoder archs)
# ===========================================================================

def attn_params(pf: ParamFactory, cfg, cross: bool = False):
    D, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    p = {
        "wq": pf.dense(D, H * hd),
        "wk": pf.dense(D, Hkv * hd),
        "wv": pf.dense(D, Hkv * hd),
        "wo": pf.dense(H * hd, D, scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = pf.ones(hd)
        p["k_norm"] = pf.ones(hd)
    return p


def attn_fwd(p, x, cfg, *, local: bool, positions, kv_ctx=None,
             cache=None, pos=None, causal=True, causal_skip=False):
    """Full-sequence (train/prefill/encoder) or single-step decode attention.

    kv_ctx: (B, Sk, D) cross-attention context (whisper decoder); when given,
    k/v come from the context and no mask/rope is applied.
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    window = cfg.sliding_window if local else 0

    q = shard((x @ p["wq"]).reshape(B, S, H, hd), "act_bthd")
    src = x if kv_ctx is None else kv_ctx
    Sk = src.shape[1]
    k = (src @ p["wk"]).reshape(B, Sk, Hkv, hd)
    v = (src @ p["wv"]).reshape(B, Sk, Hkv, hd)

    if cfg.qk_norm and kv_ctx is None:
        q = rms_headnorm(p["q_norm"], q)
        k = rms_headnorm(p["k_norm"], k)

    if cfg.rope_variant != "none" and kv_ctx is None:
        sections = (16, 24, 24) if cfg.rope_variant == "mrope" else None
        cos, sin = rope_angles(positions, hd, cfg.rope_theta, cfg.rope_pct, sections)
        q = apply_rope(q, cos, sin, cfg.rope_pct)
        k = apply_rope(k, cos, sin, cfg.rope_pct)

    new_cache = None
    if cache is not None and kv_ctx is None:
        # decode: write k/v into the (ring) cache, attend over it
        assert S == 1
        Smax = cache["k"].shape[1]
        slot = jnp.mod(pos, Smax) if window else jnp.minimum(pos, Smax - 1)
        bidx = jnp.arange(B)
        ck = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        out = decode_attention(q, ck, cv, pos, window=window)
    elif cache is not None:  # cross-attention decode: cache holds ctx k/v
        out = decode_attention(q, cache["k"], cache["v"],
                               jnp.full((B,), Sk - 1), window=0)
        new_cache = cache
    else:
        out = chunked_attention(q, k, v, causal=causal and kv_ctx is None,
                                window=window, causal_skip=causal_skip)
    out = shard(out, "act_bthd")
    return (out.reshape(B, S, H * hd) @ p["wo"]), new_cache


def attn_cache_spec(cfg, batch: int, max_seq: int, local: bool, dtype):
    window = cfg.sliding_window if local else 0
    Smax = min(window, max_seq) if window else max_seq
    shp = (batch, Smax, cfg.num_kv_heads, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(shp, dtype), "v": jax.ShapeDtypeStruct(shp, dtype)}


# ===========================================================================
# MLA attention (deepseek-v2): low-rank kv compression, absorbed decode
# ===========================================================================

def mla_params(pf: ParamFactory, cfg):
    D, H = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lkv, lq = cfg.kv_lora_rank, cfg.q_lora_rank
    return {
        "w_dq": pf.dense(D, lq),
        "q_norm": pf.ones(lq),
        "w_uq": pf.dense(lq, H * (dn + dr)),
        "w_dkv": pf.dense(D, lkv),
        "kv_norm": pf.ones(lkv),
        "w_kr": pf.dense(D, dr),
        "w_uk": pf.dense(lkv, H * dn),
        "w_uv": pf.dense(lkv, H * dv),
        "wo": pf.dense(H * dv, D, scale=1.0 / math.sqrt(H * dv)),
    }


def mla_fwd(p, x, cfg, *, positions, cache=None, pos=None, causal_skip=False):
    B, S, D = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lkv = cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(dn + dr)

    cq = rms_headnorm(p["q_norm"], x @ p["w_dq"])
    q = (cq @ p["w_uq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    c_kv = rms_headnorm(p["kv_norm"], x @ p["w_dkv"])          # (B,S,lkv)
    k_rope = (x @ p["w_kr"]).reshape(B, S, 1, dr)

    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)

    if cache is None:
        # naive expanded form for train/prefill
        k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, dn)
        v = (c_kv @ p["w_uv"]).reshape(B, S, H, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], -1)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        out = chunked_attention(qf, k, v, causal=True, scale=scale,
                                causal_skip=causal_skip)
        out = out.reshape(B, S, H * dv)
        return out @ p["wo"], None

    # ---- absorbed decode: score/value directly against the latent cache ----
    assert S == 1
    Smax = cache["c_kv"].shape[1]
    bidx = jnp.arange(B)
    c_cache = cache["c_kv"].at[bidx, pos].set(c_kv[:, 0].astype(cache["c_kv"].dtype))
    r_cache = cache["k_rope"].at[bidx, pos].set(k_rope[:, 0, 0].astype(cache["k_rope"].dtype))
    new_cache = {"c_kv": c_cache, "k_rope": r_cache}

    w_uk = p["w_uk"].reshape(lkv, H, dn)
    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(F32),
                       w_uk.astype(F32))                        # (B,H,lkv)
    s = (jnp.einsum("bhl,bsl->bhs", q_abs, c_cache.astype(F32))
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(F32),
                      r_cache.astype(F32))) * scale
    valid = jnp.arange(Smax)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, :], s, NEG)
    pattn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", pattn, c_cache.astype(F32))  # (B,H,lkv)
    w_uv = p["w_uv"].reshape(lkv, H, dv)
    out = jnp.einsum("bhl,lhd->bhd", ctx, w_uv.astype(F32))
    out = out.reshape(B, 1, H * dv).astype(x.dtype)
    return out @ p["wo"], new_cache


def mla_cache_spec(cfg, batch: int, max_seq: int, dtype):
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    }


# ===========================================================================
# MLP + MoE
# ===========================================================================

def mlp_params(pf: ParamFactory, d_model: int, d_ff: int, gated: bool):
    p = {"w_up": pf.dense(d_model, d_ff),
         "w_down": pf.dense(d_ff, d_model, scale=1.0 / math.sqrt(d_ff))}
    if gated:
        p["w_gate"] = pf.dense(d_model, d_ff)
    return p


def _act(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


def mlp_fwd(p, x, act: str, gated: bool):
    up = shard(x @ p["w_up"], "act_btf")
    h = _act(act)(x @ p["w_gate"]) * up if gated else _act(act)(up)
    return h @ p["w_down"]


def moe_params(pf: ParamFactory, cfg):
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    p = {
        "router": pf.dense(D, E, scale=0.02),
        "w_gate": pf.dense(E, D, F),
        "w_up": pf.dense(E, D, F),
        "w_down": pf.dense(E, F, D, scale=1.0 / math.sqrt(F)),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_params(pf, D, cfg.num_shared_experts * cfg.moe_d_ff, True)
    return p


def _moe_route(xt, router, E, K, aux_coef):
    """Router: (T, D) -> (top_p, top_e (T,K), aux). Shared by both paths."""
    T = xt.shape[0]
    logits = (xt @ router).astype(F32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = lax.top_k(probs, K)                    # (T,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    density = jnp.zeros((E,), F32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(density * probs.mean(0)) * aux_coef
    return top_p, top_e, aux


def moe_fwd(p, x, cfg):
    """Sort-based dropping MoE. Returns (out, aux_loss).

    Two dispatch layouts:

    * flat (moe_groups=0): one global sort over all T*K assignments,
      capacity C = ceil(T*k*cf / E). Simple, but under GSPMD the global
      argsort + scatter/gather across the (data x model) mesh all-gathers
      the full token buffer — the dominant collective in the deepseek-v2
      baseline (§Perf).
    * grouped (moe_groups=G): tokens are split into G groups (= data
      shards); routing, sort, capacity and dispatch are GROUP-LOCAL, the
      dispatch buffer is (G, E, C_g, D) sharded (data, model) on (G, E),
      expert matmuls contract locally against the E-sharded weights, and
      the combine is a pre-weighted scatter-add back to (G, T_loc, D) —
      lowering to one partial-sum all-reduce over the model axis instead
      of full-buffer all-gathers.
    """
    orig_shape = x.shape
    D, E, K = cfg.d_model, cfg.num_experts, cfg.moe_top_k
    xt = x.reshape(-1, D)
    T = xt.shape[0]
    G = cfg.moe_groups
    # grouped dispatch only pays off with enough tokens per group: decode
    # steps (T = batch, ~8 tokens/group) regressed 2.1x under it (§Perf)
    if G and T % G == 0 and T // G >= 64:
        y, aux = _moe_grouped(p, xt, cfg, G)
        if cfg.num_shared_experts:
            y = y + mlp_fwd(p["shared"], xt, cfg.act, True)
        return y.reshape(orig_shape), aux

    top_p, top_e, aux = _moe_route(xt, p["router"], E, K,
                                   cfg.router_aux_coef)
    C = max(1, math.ceil(T * K * cfg.capacity_factor / E))
    flat_e = top_e.reshape(-1)                            # (T*K,) token-major
    flat_w = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(T * K) - starts[sorted_e]
    slot_sorted = jnp.where(rank_sorted < C, sorted_e * C + rank_sorted, E * C)

    xs = xt[flat_t[order]]                                # (T*K, D)
    buf = jnp.zeros((E * C, D), xt.dtype).at[slot_sorted].set(xs, mode="drop")
    buf = shard(buf.reshape(E, C, D), "moe_ecd")

    h = _act(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = shard(h, "moe_ecf")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, D)

    y_sorted = jnp.take(out_buf, slot_sorted, axis=0, mode="fill", fill_value=0)
    y_flat = jnp.zeros((T * K, D), xt.dtype).at[order].set(y_sorted)
    y = (y_flat.reshape(T, K, D) * flat_w.reshape(T, K, 1).astype(xt.dtype)).sum(1)

    if cfg.num_shared_experts:
        y = y + mlp_fwd(p["shared"], xt, cfg.act, True)
    return y.reshape(orig_shape), aux


def _moe_grouped(p, xt, cfg, G: int):
    """Group-local dispatch (see moe_fwd docstring)."""
    D, E, K = cfg.d_model, cfg.num_experts, cfg.moe_top_k
    T = xt.shape[0]
    Tl = T // G
    C = max(1, math.ceil(Tl * K * cfg.capacity_factor / E))

    xg = shard(xt.reshape(G, Tl, D), "moe_gtd")

    def dispatch(xt_g):
        top_p, top_e, aux = _moe_route(xt_g, p["router"], E, K,
                                       cfg.router_aux_coef)
        flat_e = top_e.reshape(-1)
        flat_w = top_p.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tl), K)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(Tl * K) - starts[sorted_e]
        slot = jnp.where(rank < C, sorted_e * C + rank, E * C)
        buf = jnp.zeros((E * C, D), xt_g.dtype).at[slot].set(
            xt_g[flat_t[order]], mode="drop")
        # slot -> (token, gate weight) maps for the scatter-add combine
        tok_of = jnp.full((E * C,), Tl, jnp.int32).at[slot].set(
            flat_t[order], mode="drop")
        w_of = jnp.zeros((E * C,), F32).at[slot].set(
            flat_w[order], mode="drop")
        return buf.reshape(E, C, D), tok_of, w_of, aux

    buf, tok_of, w_of, aux = jax.vmap(dispatch)(xg)       # (G,E,C,D)...
    # NOTE (§Perf deepseek EXP-D, net-refuted): a model-REPLICATED buf
    # ("moe_gbuf") removes the scatter's replicate+AR+slice fallback
    # (coll -32%) but the 16x read amplification of the replicated buffer
    # costs more than the AR saved (bytes +8%, bound 86.6s vs 80.0s).
    buf = shard(buf, "moe_gecd")

    h = _act(cfg.act)(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = shard(h, "moe_gecf")
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])    # (G,E,C,D)
    out = shard(out, "moe_gecd")
    # pre-weight rows by their token's gate weight, then scatter-add back:
    # updates are E-sharded (model axis), destination is model-replicated
    # -> partial local scatter + ONE all-reduce over the model axis.
    out = out * w_of.reshape(G, E, C, 1).astype(out.dtype)

    # combine accumulates in the compute dtype: at most top_k(<=8) summands
    # per token, and keeping it bf16 halves the model-axis partial-sum
    # all-reduce payload (§Perf deepseek EXP-C)
    def combine(out_g, tok_g):
        return jnp.zeros((Tl, D), out_g.dtype).at[tok_g].add(
            out_g.reshape(E * C, D), mode="drop")

    y = jax.vmap(combine)(out, tok_of.reshape(G, E * C))
    y = shard(y.astype(xt.dtype), "moe_gtd")
    return y.reshape(T, D), aux.mean()


# ===========================================================================
# Causal depthwise conv (mamba / mLSTM front conv)
# ===========================================================================

def causal_conv1d(x, w, b, state=None):
    """x: (B,S,C), w: (ksize,C), b: (C,). state: (B,ksize-1,C) for decode.
    Returns (y, new_state)."""
    ksize = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], ksize - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_state = xp[:, -(ksize - 1):, :] if ksize > 1 else None
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(ksize - 1):, :]
    windows = jnp.stack([xp[:, i:i + x.shape[1], :] for i in range(ksize)], 2)
    y = jnp.einsum("bskc,kc->bsc", windows, w.astype(x.dtype)) + b.astype(x.dtype)
    return y, new_state


# ===========================================================================
# Chunked cross-entropy (never materializes (B,S,V) logits)
# ===========================================================================

def chunked_ce_loss(h, w_head, labels, *, chunk: int = 512, mask=None):
    """h: (B,S,D); w_head: (D,V); labels: (B,S). Mean CE over unmasked tokens."""
    B, S, D = h.shape
    c = _pick_chunk(S, chunk)
    n = S // c
    hs = h.reshape(B, n, c, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, c).transpose(1, 0, 2)
    ms = (mask.reshape(B, n, c).transpose(1, 0, 2) if mask is not None
          else jnp.ones((n, B, c), F32))

    def body(carry, xs):
        tot, cnt = carry
        hc, lc, mc = xs
        logits = (hc @ w_head).astype(F32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, lc[..., None], -1)[..., 0]
        tot = tot + ((logz - gold) * mc).sum()
        return (tot, cnt + mc.sum()), ()

    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), F32), jnp.zeros((), F32)),
                             (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
