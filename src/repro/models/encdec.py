"""Whisper-style encoder-decoder assembly.

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings of shape (B, encoder_seq, d_model).
The encoder is a bidirectional transformer over the frames; the decoder is
a causal transformer with interleaved cross-attention to the encoder output.

Decode-time contract: the cross-attention k/v are computed once at prefill
and live in the cache (`xk`/`xv` per decoder layer); per-step decode never
re-touches the encoder.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.ctx import shard
from repro.models import layers as L
from repro.models.layers import ParamFactory

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def _enc_layer_params(pf: ParamFactory, cfg):
    return {
        "norm1": L.norm_params(pf, cfg.d_model, cfg.norm),
        "attn": L.attn_params(pf, cfg),
        "norm2": L.norm_params(pf, cfg.d_model, cfg.norm),
        "mlp": L.mlp_params(pf, cfg.d_model, cfg.d_ff, cfg.mlp_gated),
    }


def _dec_layer_params(pf: ParamFactory, cfg):
    return {
        "norm1": L.norm_params(pf, cfg.d_model, cfg.norm),
        "self_attn": L.attn_params(pf, cfg),
        "norm_x": L.norm_params(pf, cfg.d_model, cfg.norm),
        "cross_attn": L.attn_params(pf, cfg, cross=True),
        "norm2": L.norm_params(pf, cfg.d_model, cfg.norm),
        "mlp": L.mlp_params(pf, cfg.d_model, cfg.d_ff, cfg.mlp_gated),
    }


def _stacked(pf: ParamFactory, n: int, builder):
    if pf.key is None:
        one = builder()
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), one)
    reps = [builder() for _ in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *reps)


def encdec_params(cfg, key: Optional[jax.Array]):
    pf = ParamFactory(key, cfg.dtype)
    return {
        "embed": pf.dense(cfg.vocab_size, cfg.d_model, scale=0.02),
        "dec_pos": pf.dense(cfg.max_positions, cfg.d_model, scale=0.01),
        "enc_pos": pf.dense(cfg.encoder_seq, cfg.d_model, scale=0.01),
        "enc_layers": _stacked(pf, cfg.num_encoder_layers,
                               lambda: _enc_layer_params(pf, cfg)),
        "enc_norm": L.norm_params(pf, cfg.d_model, cfg.norm),
        "dec_layers": _stacked(pf, cfg.num_layers,
                               lambda: _dec_layer_params(pf, cfg)),
        "final_norm": L.norm_params(pf, cfg.d_model, cfg.norm),
        # whisper ties the output head to the token embedding
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params, frames, cfg, *, remat: bool = True):
    """frames: (B, Se, D) stubbed conv-frontend output."""
    Se = frames.shape[1]
    h = frames + params["enc_pos"][:Se].astype(frames.dtype)
    h = shard(h, "act_btd")
    positions = jnp.arange(Se)

    def body(h, lp):
        a = L.apply_norm(lp["norm1"], h, cfg.norm, cfg.norm_eps)
        a, _ = L.attn_fwd(lp["attn"], a, cfg, local=False, positions=positions,
                          causal=False)
        h = shard(h + a, "act_btd")
        m = L.apply_norm(lp["norm2"], h, cfg.norm, cfg.norm_eps)
        m = L.mlp_fwd(lp["mlp"], m, cfg.act, cfg.mlp_gated)
        h = shard(h + m, "act_btd")
        return h, ()

    fn = jax.checkpoint(body) if remat else body
    h, _ = lax.scan(fn, h, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], h, cfg.norm, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _dec_block(lp, x, ctx, cfg, *, positions, self_cache, pos, xkv,
               causal_skip=False):
    """One decoder block. xkv: precomputed cross-attn {"k","v"} (decode) or
    None (train/prefill: projected from ctx)."""
    B, S, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd

    a = L.apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    a, new_self = L.attn_fwd(lp["self_attn"], a, cfg, local=False,
                             positions=positions, cache=self_cache, pos=pos,
                             causal=True, causal_skip=causal_skip)
    x = shard(x + a, "act_btd")

    c = L.apply_norm(lp["norm_x"], x, cfg.norm, cfg.norm_eps)
    cp = lp["cross_attn"]
    q = (c @ cp["wq"]).reshape(B, S, H, hd)
    if xkv is None:
        Sk = ctx.shape[1]
        k = (ctx @ cp["wk"]).reshape(B, Sk, Hkv, hd)
        v = (ctx @ cp["wv"]).reshape(B, Sk, Hkv, hd)
        out = L.chunked_attention(q, k, v, causal=False)
        new_xkv = {"k": k, "v": v}
    else:
        Sk = xkv["k"].shape[1]
        out = L.decode_attention(q, xkv["k"], xkv["v"],
                                 jnp.full((B,), Sk - 1, jnp.int32))
        new_xkv = xkv
    c = out.reshape(B, S, H * hd) @ cp["wo"]
    x = shard(x + c, "act_btd")

    m = L.apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    m = L.mlp_fwd(lp["mlp"], m, cfg.act, cfg.mlp_gated)
    x = shard(x + m, "act_btd")
    return x, new_self, new_xkv


def decode_stack(params, tokens, ctx, cfg, *, cache=None, pos=None,
                 remat: bool = True, causal_skip: bool = False):
    """tokens: (B,S) int; ctx: (B,Se,D) encoder output (or None at decode).

    Returns (h, new_cache). Cache pytree per layer:
      {"k","v"} self-attn ring + {"xk","xv"} cross k/v.
    """
    B, S = tokens.shape
    decode = cache is not None
    h = jnp.take(params["embed"], tokens, axis=0)
    if decode:
        h = h + jnp.take(params["dec_pos"], pos, axis=0)[:, None, :].astype(h.dtype)
        positions = pos[:, None]
    else:
        h = h + params["dec_pos"][:S].astype(h.dtype)
        positions = jnp.arange(S)
    h = shard(h, "act_btd")

    def body(carry, xs):
        x = carry
        if decode:
            lp, lc = xs
            self_cache = {"k": lc["k"], "v": lc["v"]}
            xkv = {"k": lc["xk"], "v": lc["xv"]}
        else:
            (lp,) = xs
            self_cache, xkv = None, None
        x, new_self, new_xkv = _dec_block(lp, x, ctx, cfg, positions=positions,
                                          self_cache=self_cache, pos=pos,
                                          xkv=xkv, causal_skip=causal_skip)
        if decode:
            out = {"k": new_self["k"], "v": new_self["v"],
                   "xk": new_xkv["k"], "xv": new_xkv["v"]}
        elif new_xkv is not None:
            out = {"xk": new_xkv["k"], "xv": new_xkv["v"]}
        else:
            out = 0.0
        return x, out

    fn = jax.checkpoint(body) if (remat and not decode) else body
    xs = (params["dec_layers"], cache) if decode else (params["dec_layers"],)
    h, layer_out = lax.scan(fn, h, xs)
    h = L.apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    return h, layer_out


# ---------------------------------------------------------------------------
# Top-level entry points
# ---------------------------------------------------------------------------

def encdec_loss(params, batch, cfg, *, remat: bool = True,
                causal_skip: bool = False):
    ctx = encode(params, batch["frames"], cfg, remat=remat)
    h, _ = decode_stack(params, batch["tokens"], ctx, cfg, remat=remat,
                        causal_skip=causal_skip)
    mask = None
    if "weights" in batch:
        B, S = batch["tokens"].shape
        mask = jnp.broadcast_to(batch["weights"][:, None].astype(F32), (B, S))
    return L.chunked_ce_loss(h, params["embed"].T, batch["labels"], mask=mask)


def encdec_prefill(params, batch, cfg, *, causal_skip: bool = False):
    ctx = encode(params, batch["frames"], cfg, remat=False)
    h, _ = decode_stack(params, batch["tokens"], ctx, cfg, remat=False,
                        causal_skip=causal_skip)
    return h[:, -1, :] @ params["embed"].T


def encdec_decode_step(params, batch, cfg):
    """cache: stacked per-layer {"k","v","xk","xv"} (leading num_layers dim)."""
    h, new_cache = decode_stack(params, batch["token"], None, cfg,
                                cache=batch["cache"], pos=batch["pos"],
                                remat=False)
    logits = h[:, -1, :] @ params["embed"].T
    return logits, new_cache


def encdec_cache_specs(cfg, batch: int, max_seq: int, dtype):
    nl = cfg.num_layers
    Hkv, hd, Se = cfg.num_kv_heads, cfg.hd, cfg.encoder_seq
    sd = jax.ShapeDtypeStruct
    return {
        "k": sd((nl, batch, max_seq, Hkv, hd), dtype),
        "v": sd((nl, batch, max_seq, Hkv, hd), dtype),
        "xk": sd((nl, batch, Se, Hkv, hd), dtype),
        "xv": sd((nl, batch, Se, Hkv, hd), dtype),
    }
