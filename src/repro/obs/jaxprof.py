"""Opt-in ``jax.profiler`` capture and compile-event accounting.

Two independent facilities:

* ``annotate(name)`` / ``trace(logdir)`` — named TraceAnnotation scopes
  around ``fleet_round`` / ``_local_train`` dispatches and an opt-in
  profiler trace capture. Annotations are ~free when no trace is active,
  so the engine applies them unconditionally once an observer enables
  them; ``trace`` writes a TensorBoard-loadable profile under ``logdir``.

* ``CompileWatcher`` — records XLA compile events via
  ``jax.monitoring``'s duration listeners (jaxpr trace, MLIR lowering,
  backend compile), with per-function attribution via ``track``: calls
  are synchronous, so durations arriving during a tracked window belong
  to that function, and ``_cache_size`` deltas confirm whether the call
  actually compiled. This is how batched-vs-sequential compile overhead
  lands in ``BENCH_round_engine.json``.

Everything degrades to a no-op if the running jax lacks the private
monitoring hooks — the engine must never fail because profiling is
unavailable.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

# Compile-related jax.monitoring event names (jax 0.4.x). The listener
# API has no metadata channel, hence the call-window attribution below.
_COMPILE_EVENTS = (
    "/jax/core/compile/jaxpr_trace_duration",
    "/jax/core/compile/jaxpr_to_mlir_module_duration",
    "/jax/core/compile/backend_compile_duration",
)


@contextlib.contextmanager
def annotate(name: str):
    """Named scope visible in profiler traces (no-op when not tracing)."""
    try:
        ctx = jax.profiler.TraceAnnotation(name)
    except Exception:                               # pragma: no cover
        yield
        return
    with ctx:
        yield


@contextlib.contextmanager
def trace(logdir: str):
    """Capture a jax profiler trace into ``logdir`` for the duration."""
    try:
        jax.profiler.start_trace(logdir)
        started = True
    except Exception:                               # pragma: no cover
        started = False
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:                       # pragma: no cover
                pass


class CompileWatcher:
    """Aggregates XLA compile count/time, attributable per tracked label.

    ``events`` maps monitoring-event name -> [durations]; ``by_label``
    maps a ``track`` label -> {"events": n, "seconds": s, "compiles": c}
    where ``compiles`` counts tracked calls whose jit cache actually
    grew (a new specialization was compiled).
    """

    def __init__(self):
        self.events: dict[str, list[float]] = {e: [] for e in
                                               _COMPILE_EVENTS}
        self.by_label: dict[str, dict] = {}
        self._current: Optional[str] = None
        self._installed = False

    # -- listener lifecycle --------------------------------------------------
    def _listener(self, event: str, duration: float, **kw) -> None:
        if event not in self.events:
            return
        self.events[event].append(duration)
        if self._current is not None:
            slot = self.by_label[self._current]
            slot["events"] += 1
            slot["seconds"] += duration

    def install(self) -> "CompileWatcher":
        if not self._installed:
            try:
                jax.monitoring.register_event_duration_secs_listener(
                    self._listener)
                self._installed = True
            except Exception:                       # pragma: no cover
                pass
        return self

    def uninstall(self) -> None:
        if not self._installed:
            return
        self._installed = False
        try:                                        # no public unregister
            from jax._src import monitoring as _m
            _m._unregister_event_duration_listener_by_callback(
                self._listener)
        except Exception:                           # pragma: no cover
            pass

    def __enter__(self):
        return self.install()

    def __exit__(self, *exc):
        self.uninstall()

    # -- attribution ---------------------------------------------------------
    @contextlib.contextmanager
    def track(self, label: str, fn=None):
        """Attribute compile events fired inside this scope to ``label``.
        Pass the jitted ``fn`` to also detect cache growth (a compile
        this call actually triggered, not a warm hit)."""
        slot = self.by_label.setdefault(
            label, {"events": 0, "seconds": 0.0, "compiles": 0,
                    "calls": 0})
        slot["calls"] += 1
        before = _cache_size(fn)
        prev, self._current = self._current, label
        try:
            yield slot
        finally:
            self._current = prev
            if _cache_size(fn) > before:
                slot["compiles"] += 1

    # -- export --------------------------------------------------------------
    def summary(self) -> dict:
        return {
            "total": {
                "events": sum(len(v) for v in self.events.values()),
                "seconds": sum(sum(v) for v in self.events.values()),
            },
            "by_event": {e.rsplit("/", 1)[-1]:
                         {"count": len(v), "seconds": sum(v)}
                         for e, v in self.events.items()},
            "by_label": {k: dict(v) for k, v in self.by_label.items()},
        }


def _cache_size(fn) -> int:
    if fn is None:
        return 0
    try:
        return fn._cache_size()
    except Exception:                               # pragma: no cover
        return 0
