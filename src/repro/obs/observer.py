"""EngineObserver: the round engine's observability hook surface.

The engine (and its pacing / transport / mixing policies) call these
hooks with the EXACT floats they hand the ``EnergyLedger``, at the exact
call sites that mutate it — observer events are the only new code on the
hot path, and every hook site is guarded with ``if obs is not None`` so
a disabled observer costs one pointer comparison (golden-ledger
bit-parity is preserved by construction; pinned in tests/test_obs.py).

``EngineObserver`` is the no-op base — subclass and override what you
need. ``TracingObserver`` is the full implementation: it feeds a
``SpanTracer`` (JSONL + Chrome trace), a ``Metrics`` registry decomposing
the ledger per round x cluster x phase and per link class, and a
**mirror ledger** that replays every hook value through the same
``EnergyLedger`` ``add_*`` methods in arrival order — so at session end
``mirror`` equals the engine's ledger bit-for-bit, proving the trace
captured every joule/second exactly once (DESIGN.md §10).
"""
from __future__ import annotations

from typing import Optional

from repro.core.energy import EnergyLedger
from repro.obs.metrics import Metrics
from repro.obs.trace import SpanTracer


class EngineObserver:
    """No-op base: every hook the engine stack calls, in call order.

    Hook arguments are host-side scalars only — observers must never
    touch device arrays, the engine's RNG streams, or the real ledger
    (read-only observation; the engine does not read anything back).
    """

    def session_start(self, algo: str, plan, cfg, sim_t: float) -> None:
        """After the cluster plan is built, before bootstrap comm."""

    def round_start(self, r: int, sim_t: float) -> None:
        pass

    def select(self, r: int, kc: int, sel) -> None:
        """After SelectionPolicy.select for cluster ``kc``."""

    def train(self, kc: int, energy_j: float, barrier_s: float) -> None:
        """Train energy + cluster barrier, as charged to the ledger."""

    def wait(self, seconds: float, cause: str,
             kc: Optional[int] = None) -> None:
        """Latency-only idle time, as charged to the ledger."""

    def comm(self, link: str, kc: Optional[int], n: int, bits: float,
             energy_j: float, time_s: float) -> None:
        """One Transport message batch (link in {gs, intra, inter})."""

    def straggler(self, kc: int, action: str) -> None:
        """Semi-sync deadline events: action in {stash, fold}."""

    def async_merge(self, kc: int, rank: int, alpha: float) -> None:
        """Async pacing: cluster kc merged at arrival ``rank`` with
        staleness weight ``alpha``."""

    def sim_event(self, etype: str, sim_t: float,
                  cluster: Optional[int] = None,
                  sat: Optional[int] = None, seq: int = 0,
                  **payload) -> None:
        """One event popped from the discrete-event kernel
        (repro.sim.events), in kernel order: ``etype`` is the kernel
        taxonomy (contact_open/contact_close/train_done/transfer_done/
        straggler_timeout/merge_commit), ``sim_t`` the absolute sim time
        it fired. Kernel events are timing/ordering observability only —
        implementations must never route them into the mirror ledger
        (the accounting hooks above already carry every joule/second)."""

    def fault(self, fkind: str, sim_t: float,
              cluster: Optional[int] = None,
              sat: Optional[int] = None, **info) -> None:
        """One injected fault (or its paired recovery event) applied by a
        ``repro.faults.FaultInjector``: ``fkind`` is the kernel fault
        taxonomy (link_down/link_up/sat_crash/sat_reboot/master_fail/
        payload_corrupt/payload_loss/clock_drift), ``sim_t`` the sim time
        it landed. Timeline observability only — any energy/time cost of
        a fault flows through the accounting hooks above; implementations
        must never route fault events into a mirror ledger."""

    def recovery(self, action: str, sim_t: float,
                 cluster: Optional[int] = None,
                 sat: Optional[int] = None, **info) -> None:
        """One recovery action the engine stack took under faults:
        ``action`` in {retry, retransmit, drop, failover,
        failover_exhausted, skip_crashed}. Same contract as ``fault``:
        the charged cost (retry energy, backoff waits) already went
        through ``comm``/``wait`` — never mirror these."""

    def robust_reject(self, kc: Optional[int], reason: str,
                      **info) -> None:
        """The robust aggregation layer (repro.fl.robust, DESIGN.md §14)
        rejected or tamed cluster ``kc``'s delivered update this merge:
        ``reason`` in {nonfinite, norm_clip, krum}. Value-layer
        observability only — robust aggregation never touches the
        ledger, so implementations must never mirror these."""

    def quorum(self, kc: int, frac: float, ok: bool) -> None:
        """Quorum gate verdict for cluster ``kc`` at this merge:
        ``frac`` is the valid-delivered fraction, ``ok`` False when the
        cluster fell below quorum and carries its model forward as a
        degraded round. Same no-mirror contract as ``robust_reject``."""

    def note(self, name: str, **fields) -> None:
        """Free-form instant (master migration, gossip consensus, ...)."""

    def phase_start(self, name: str, sim_t: Optional[float] = None) -> None:
        pass

    def phase_end(self, name: str, sim_t0: Optional[float] = None,
                  sim_dur: Optional[float] = None) -> None:
        pass

    def round_end(self, r: int, sim_t: float, sim_dur: float) -> None:
        pass

    def session_end(self, sim_t: float, ledger: EnergyLedger) -> None:
        pass


class TracingObserver(EngineObserver):
    """Spans + metrics + bit-exact ledger mirror (see module docstring).

    ``jsonl_path``: stream events to this file as they happen (optional;
    the in-memory trace is always kept). Out-of-round hooks (bootstrap /
    finalize comm) are attributed to the session phase they occur in;
    in-round hooks get the current round index automatically.
    """

    def __init__(self, jsonl_path: Optional[str] = None):
        self.tracer = SpanTracer(jsonl_path)
        self.metrics = Metrics()
        self.mirror = EnergyLedger()
        self._round: Optional[int] = None
        self._phase = "bootstrap"
        self._t_round = 0.0
        self._t_round_host = 0.0
        self.algo = "?"

    # -- session -------------------------------------------------------------
    def session_start(self, algo, plan, cfg, sim_t):
        self.algo = algo
        self.mirror.wall_clock_s = sim_t      # resumed sessions start hot
        self.tracer.emit("session_start", algo=algo,
                         n_clusters=plan.n_clusters, sim_t=sim_t,
                         rounds=getattr(cfg, "rounds", None))

    def round_start(self, r, sim_t):
        self._round, self._phase = r, "round"
        self._t_round = sim_t
        self._t_round_host = self.tracer.now()
        self.tracer.emit("round_start", round=r, sim_t=sim_t)

    def select(self, r, kc, sel):
        engaged = int(len(sel.ids))
        trained = int(sel.mask.sum())
        self.metrics.count("skipped", engaged - trained, round=r, cluster=kc)
        self.tracer.emit("select", round=r, cluster=kc, engaged=engaged,
                         trained=trained, skipped=engaged - trained)

    def train(self, kc, energy_j, barrier_s):
        self.mirror.add_train(energy_j, barrier_s)
        r = self._round
        self.metrics.count("train_joules", energy_j, round=r, cluster=kc)
        self.metrics.count("barrier_s", barrier_s, round=r, cluster=kc)
        self.tracer.emit("train", round=r, cluster=kc,
                         energy_j=float(energy_j),
                         barrier_s=float(barrier_s), sim_t0=self._t_round)

    def wait(self, seconds, cause, kc=None):
        self.mirror.add_wait(seconds)
        self.metrics.count("wait_s", seconds, round=self._round, cluster=kc,
                           cause=cause, phase=self._phase)
        self.tracer.emit("wait", seconds=float(seconds), cause=cause,
                         round=self._round, cluster=kc)

    def comm(self, link, kc, n, bits, energy_j, time_s):
        getattr(self.mirror, f"add_{link}")(n, energy_j, time_s)
        lab = dict(link=link, round=self._round, cluster=kc,
                   phase=self._phase)
        self.metrics.count("msgs", n, **lab)
        self.metrics.count("comm_bits", n * bits, **lab)
        self.metrics.count("comm_joules", energy_j, **lab)
        self.metrics.count("comm_seconds", time_s, **lab)
        # link-class reconciliation series, accumulated in strict arrival
        # order across links sharing a ledger field (intra+inter -> lisl)
        fld = "gs" if link == "gs" else "lisl"
        self.metrics.count(f"{fld}_joules_inorder", energy_j)
        self.tracer.emit("comm", link=link, cluster=kc, n=int(n),
                         bits=float(n * bits), energy_j=float(energy_j),
                         time_s=float(time_s), phase=self._phase,
                         round=self._round, sim_t0=self._t_round)

    def straggler(self, kc, action):
        self.metrics.count(f"straggler_{action}", 1, round=self._round,
                           cluster=kc)
        self.tracer.emit("straggler", round=self._round, cluster=kc,
                         action=action)

    def async_merge(self, kc, rank, alpha):
        self.metrics.observe("async_rank", rank, cluster=kc)
        self.tracer.emit("async_merge", round=self._round, cluster=kc,
                         rank=int(rank), alpha=float(alpha))

    def sim_event(self, etype, sim_t, cluster=None, sat=None, seq=0,
                  **payload):
        # the kernel stamps its own round index in the payload (events
        # can pop a round after they were scheduled); fall back to the
        # observer's current round for sources that do not
        rnd = payload.pop("round", self._round)
        self.metrics.count("sim_events", 1, etype=etype)
        self.tracer.emit("sim_event", etype=etype, sim_t=float(sim_t),
                         seq=int(seq), cluster=cluster, sat=sat, round=rnd,
                         **{k: float(v) for k, v in payload.items()})

    def fault(self, fkind, sim_t, cluster=None, sat=None, **info):
        # timeline + counters only — the mirror ledger must NOT see
        # fault events (their cost arrives via comm/wait, exactly once)
        self.metrics.count("faults", 1, fkind=fkind)
        self.tracer.emit("fault", fkind=fkind, sim_t=float(sim_t),
                         cluster=cluster, sat=sat, round=self._round,
                         **info)

    def recovery(self, action, sim_t, cluster=None, sat=None, **info):
        self.metrics.count("recoveries", 1, action=action)
        self.tracer.emit("recovery", action=action, sim_t=float(sim_t),
                         cluster=cluster, sat=sat, round=self._round,
                         **info)

    def robust_reject(self, kc, reason, **info):
        self.metrics.count("robust_rejects", 1, reason=reason)
        self.tracer.emit("robust_reject", round=self._round,
                         cluster=None if kc is None else int(kc),
                         reason=reason, **info)

    def quorum(self, kc, frac, ok):
        if not ok:
            self.metrics.count("quorum_degraded", 1, cluster=kc)
        self.metrics.observe("quorum_frac", float(frac))
        self.tracer.emit("quorum", round=self._round, cluster=int(kc),
                         frac=float(frac), ok=int(ok))

    def note(self, name, **fields):
        self.tracer.emit("note", name=name, **fields)

    def phase_start(self, name, sim_t=None):
        self.tracer.begin_span(name)

    def phase_end(self, name, sim_t0=None, sim_dur=None):
        self.tracer.end_span(name, round=self._round,
                             sim_t0=self._t_round if sim_t0 is None
                             else sim_t0, sim_dur=sim_dur)

    def round_end(self, r, sim_t, sim_dur):
        self.metrics.observe("round_latency_s", sim_dur)
        self.tracer.emit("round_end", round=r, sim_t=sim_t,
                         sim_dur=sim_dur,
                         host_dur=self.tracer.now() - self._t_round_host)
        self._round, self._phase = None, "finalize"

    def session_end(self, sim_t, ledger):
        self.mirror.wall_clock_s = sim_t
        self.tracer.emit("session_end", sim_t=sim_t,
                         ledger={k: v for k, v in ledger.row().items()})
        self.tracer.close()

    # -- reconciliation ------------------------------------------------------
    def reconcile(self, ledger: EnergyLedger) -> dict:
        """Field-by-field comparison of the mirror against the engine's
        ledger. ``exact`` is True only when EVERY field is bit-equal —
        the acceptance check of DESIGN.md §10."""
        a, b = self.mirror.snapshot(), ledger.snapshot()
        fields = {k: {"mirror": a[k], "ledger": b[k], "equal": a[k] == b[k]}
                  for k in a}
        return {"exact": all(v["equal"] for v in fields.values()),
                "fields": fields}
