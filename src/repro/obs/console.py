"""Console logger/sink for benchmarks and examples.

The repo's human-facing scripts print run headers, per-round lines and
result tables. This module gives them one consistent sink instead of
bare ``print()``: text mode by default, structured JSON-lines mode when
``REPRO_LOG_JSON=1`` is set — so benchmark output is machine-parseable
with the same event discipline as the trace JSONL.

Usage::

    from repro.obs import get_logger
    log = get_logger("benchmarks.run")
    log.info("round complete", round=3, acc=0.91)   # labelled fields
    log.raw(table_string)                           # verbatim passthrough
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Optional, TextIO


def _json_mode() -> bool:
    return os.environ.get("REPRO_LOG_JSON", "") == "1"


class ConsoleLogger:
    """Named logger writing text or JSON lines to one stream."""

    def __init__(self, name: str, stream: Optional[TextIO] = None):
        self.name = name
        self._stream = stream

    @property
    def stream(self) -> TextIO:
        # resolved per write: loggers are module-level singletons, and
        # sys.stdout may be swapped after import (pytest capture, redirects)
        return self._stream if self._stream is not None else sys.stdout

    def _write(self, level: str, msg: str, fields: dict) -> None:
        if _json_mode():
            rec = {"t": time.time(), "logger": self.name, "level": level,
                   "msg": msg, **fields}
            self.stream.write(json.dumps(rec, default=str) + "\n")
        else:
            tail = "".join(f"  {k}={_fmt(v)}" for k, v in fields.items())
            self.stream.write(f"{msg}{tail}\n")
        self.stream.flush()

    def info(self, msg: str, **fields) -> None:
        self._write("info", msg, fields)

    def warn(self, msg: str, **fields) -> None:
        if not _json_mode():
            msg = f"WARNING: {msg}"
        self._write("warn", msg, fields)

    def raw(self, text: str = "") -> None:
        """Verbatim line(s): preformatted tables, blank separators.
        In JSON mode each line becomes a {"raw": ...} record."""
        if _json_mode():
            for line in text.split("\n"):
                self.stream.write(json.dumps(
                    {"t": time.time(), "logger": self.name,
                     "raw": line}) + "\n")
        else:
            self.stream.write(text + "\n")
        self.stream.flush()


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


_loggers: dict[str, ConsoleLogger] = {}


def get_logger(name: str) -> ConsoleLogger:
    """Process-wide logger registry (one instance per name)."""
    if name not in _loggers:
        _loggers[name] = ConsoleLogger(name)
    return _loggers[name]
