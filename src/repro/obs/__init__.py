"""repro.obs: tracing, metrics, and profiler hooks for the round engine.

See DESIGN.md §10. Public surface:

* ``EngineObserver`` / ``TracingObserver`` — engine hook protocol + the
  full tracer/metrics/mirror-ledger implementation.
* ``SpanTracer`` / ``validate_event`` / ``load_events`` — versioned
  JSONL trace events and Chrome trace export.
* ``Metrics`` — counter/gauge/histogram registry.
* ``get_logger`` — console sink replacing bare print() in benchmarks.
* ``annotate`` / ``trace`` / ``CompileWatcher`` — jax profiler hooks.
"""
from repro.obs.console import ConsoleLogger, get_logger
from repro.obs.jaxprof import CompileWatcher, annotate, trace
from repro.obs.metrics import Metrics
from repro.obs.observer import EngineObserver, TracingObserver
from repro.obs.trace import (TRACE_SCHEMA_VERSION, SpanTracer, load_events,
                             validate_event)

__all__ = [
    "CompileWatcher", "ConsoleLogger", "EngineObserver", "Metrics",
    "SpanTracer", "TRACE_SCHEMA_VERSION", "TracingObserver", "annotate",
    "get_logger", "load_events", "trace", "validate_event",
]
