"""Render a run's trace JSONL into the paper-style breakdown tables.

``summarize(events)`` recomputes — from the trace alone, no ledger —
the columns the paper reports: per-phase energy (train / intra / inter /
GS), GS contact count, wait time, and the round-latency histogram.
Because the observer emitted every ledger charge as an event in order,
the in-order sums here reconcile with ``EnergyLedger.row()`` exactly
(tests/test_obs.py pins this).

CLI::

    python -m repro.obs.report run_trace.jsonl [more.jsonl ...]

prints one breakdown row per trace (per-method comparison when each
method wrote its own trace file).
"""
from __future__ import annotations

import sys

from repro.obs.trace import load_events


def summarize(events: list[dict]) -> dict:
    """Paper-style totals from trace events alone (see module doc)."""
    s = {"algo": "?", "rounds": 0,
         "train_j": 0.0, "intra_j": 0.0, "inter_j": 0.0, "gs_j": 0.0,
         "lisl_j": 0.0,    # in event order across intra+inter, so this
                           # one field reconciles bit-exact with the
                           # ledger's interleaved lisl_energy_j
         "gs_comm": 0, "intra_comm": 0, "inter_comm": 0,
         "gs_bits": 0.0, "lisl_bits": 0.0,
         "wait_s": 0.0, "sim_time_s": 0.0,
         "round_latencies": [], "wait_by_cause": {}, "sim_events": {},
         "faults": {}, "recoveries": {},
         "robust_rejects": {}, "degraded_rounds": 0, "quorum_checks": 0}
    for ev in events:
        kind = ev["kind"]
        if kind == "session_start":
            s["algo"] = ev["algo"]
        elif kind == "train":
            s["train_j"] += ev["energy_j"]
        elif kind == "comm":
            link = ev["link"]
            s[f"{link}_j"] += ev["energy_j"]
            s[f"{link}_comm"] += ev["n"]
            if link == "gs":
                s["gs_bits"] += ev["bits"]
            else:
                s["lisl_bits"] += ev["bits"]
                s["lisl_j"] += ev["energy_j"]
        elif kind == "wait":
            s["wait_s"] += ev["seconds"]
            c = ev.get("cause", "?")
            s["wait_by_cause"][c] = (s["wait_by_cause"].get(c, 0.0)
                                     + ev["seconds"])
        elif kind == "sim_event":
            et = ev.get("etype", "?")
            s["sim_events"][et] = s["sim_events"].get(et, 0) + 1
        elif kind == "fault":
            fk = ev.get("fkind", "?")
            s["faults"][fk] = s["faults"].get(fk, 0) + 1
        elif kind == "recovery":
            ac = ev.get("action", "?")
            s["recoveries"][ac] = s["recoveries"].get(ac, 0) + 1
        elif kind == "robust_reject":
            rs = ev.get("reason", "?")
            s["robust_rejects"][rs] = s["robust_rejects"].get(rs, 0) + 1
        elif kind == "quorum":
            s["quorum_checks"] += 1
            if not ev.get("ok"):
                s["degraded_rounds"] += 1
        elif kind == "round_end":
            s["rounds"] += 1
            s["round_latencies"].append(ev["sim_dur"])
        elif kind == "session_end":
            s["sim_time_s"] = ev["sim_t"]
    s["total_j"] = (s["train_j"] + s["intra_j"] + s["inter_j"]
                    + s["gs_j"])
    # degraded-mode surfacing (DESIGN.md §14): capped-retry payload
    # drops were previously only a ledger counter; quorum-gated
    # carry-forward rounds are new — both get first-class columns
    s["drops"] = s["recoveries"].get("drop", 0)
    return s


def latency_histogram(lats: list[float], bins: int = 8) -> list[str]:
    """ASCII histogram lines for the round-latency distribution."""
    if not lats:
        return ["  (no rounds)"]
    lo, hi = min(lats), max(lats)
    if hi == lo:
        # degenerate distribution (single-round traces, or every round
        # identical): one explicit full bin, not 8 zero-width buckets
        # with the whole mass crammed into the first
        return [f"  [{lo:9.2f}] s (all {len(lats)} round"
                f"{'s' if len(lats) != 1 else ''} identical) "
                f"{'#' * 20} {len(lats)}"]
    width = (hi - lo) / bins
    counts = [0] * bins
    for v in lats:
        counts[min(int((v - lo) / width), bins - 1)] += 1
    peak = max(counts)
    return [f"  [{lo + i * width:9.2f}, {lo + (i + 1) * width:9.2f}) s "
            f"{'#' * round(20 * c / peak):<20} {c}"
            for i, c in enumerate(counts)]


_COLS = [("method", "algo", "s"), ("rounds", "rounds", "d"),
         ("train J", "train_j", ".3g"), ("intra J", "intra_j", ".3g"),
         ("inter J", "inter_j", ".3g"), ("GS J", "gs_j", ".3g"),
         ("total J", "total_j", ".3g"), ("GS msgs", "gs_comm", "d"),
         ("LISL msgs", None, "d"), ("wait s", "wait_s", ".3g"),
         ("drops", "drops", "d"), ("degraded", "degraded_rounds", "d"),
         ("sim s", "sim_time_s", ".4g")]


def breakdown_table(summaries: list[dict]) -> str:
    """Per-method phase-energy / contact-count comparison table."""
    rows = []
    for s in summaries:
        row = []
        for title, key, fmt in _COLS:
            v = (s["intra_comm"] + s["inter_comm"]) if key is None \
                else s[key]
            row.append(format(v, fmt))
        rows.append(row)
    heads = [c[0] for c in _COLS]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(heads)]
    line = "  ".join(h.rjust(w) for h, w in zip(heads, widths))
    sep = "-" * len(line)
    body = ["  ".join(c.rjust(w) for c, w in zip(r, widths))
            for r in rows]
    return "\n".join([line, sep] + body)


def render(paths: list[str]) -> str:
    summaries = [summarize(load_events(p)) for p in paths]
    out = [breakdown_table(summaries)]
    for p, s in zip(paths, summaries):
        out.append("")
        out.append(f"{s['algo']} round-latency histogram ({p}):")
        out.extend(latency_histogram(s["round_latencies"]))
        if s["wait_by_cause"]:
            causes = ", ".join(f"{c}={v:.3g}s" for c, v in
                               sorted(s["wait_by_cause"].items()))
            out.append(f"  wait by cause: {causes}")
        if s["sim_events"]:
            evs = ", ".join(f"{k}={v}" for k, v in
                            sorted(s["sim_events"].items()))
            out.append(f"  kernel events: {evs}")
        if s["faults"]:
            fs = ", ".join(f"{k}={v}" for k, v in
                           sorted(s["faults"].items()))
            out.append(f"  faults injected: {fs}")
        if s["recoveries"]:
            rs = ", ".join(f"{k}={v}" for k, v in
                           sorted(s["recoveries"].items()))
            out.append(f"  recovery actions: {rs}")
        if s["robust_rejects"]:
            rj = ", ".join(f"{k}={v}" for k, v in
                           sorted(s["robust_rejects"].items()))
            out.append(f"  robust rejects: {rj}")
        if s["quorum_checks"]:
            out.append(f"  quorum: {s['quorum_checks']} checks, "
                       f"{s['degraded_rounds']} degraded carry-forward "
                       f"rounds")
    return "\n".join(out)


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python -m repro.obs.report TRACE.jsonl [...]",
              file=sys.stderr)
        return 2
    print(render(argv))
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
