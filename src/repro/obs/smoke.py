"""CI obs-smoke: a 3-round traced CroSatFL session, end to end.

Runs with a ``TracingObserver`` attached, then checks the whole
observability contract in one shot:

1. every emitted event validates against the versioned JSONL schema;
2. the observer's mirror ledger reconciles BIT-EXACT with the session's
   ``EnergyLedger`` (every joule/second traced exactly once);
3. the report's trace-only totals reproduce the ledger's GS contact
   count and phase-energy columns;
4. artifacts land in ``--out`` (default results/obs_smoke/): the event
   JSONL, the Perfetto-loadable ``trace.json``, the metrics JSON, and
   the rendered report table.

Exit code 0 iff all checks pass — CI uploads the artifacts either way.

    PYTHONPATH=src python -m repro.obs.smoke [--rounds 3] [--out DIR]
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

from repro.obs import TracingObserver, get_logger, validate_event
from repro.obs.report import render, summarize

log = get_logger("obs.smoke")


def build_session(observer, rounds: int, n_clients: int = 8):
    from repro.constellation import ConstellationEnv
    from repro.core.session import Session, SessionConfig
    from repro.core.starmask import StarMaskParams
    from repro.data.synth import dirichlet_partition, make_dataset

    ds = make_dataset("eurosat-sim", n=600, seed=0)
    test = make_dataset("eurosat-sim", n=200, seed=99)
    parts = dirichlet_partition(ds.y, n_clients, alpha=100.0, seed=0)
    env = ConstellationEnv(
        n_clients=n_clients,
        n_samples=np.array([len(p) for p in parts], float), seed=0)
    from repro.fl.client import ImageFLModel
    model = ImageFLModel(ds, parts, test)
    cfg = SessionConfig(edge_rounds=rounds, local_epochs=1, k_nbr=2,
                        model_bits=model.model_bits(),
                        starmask=StarMaskParams(k_max=4, m_min=2))
    return Session(cfg, env, model, observer=observer)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--out", default=os.path.join("results", "obs_smoke"))
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    jsonl = os.path.join(args.out, "trace.jsonl")
    obs = TracingObserver(jsonl)
    session = build_session(obs, args.rounds)
    _, ledger, _ = session.run()

    failures = []

    errs = [f"event {i}: {e}" for i, ev in enumerate(obs.tracer.events)
            for e in validate_event(ev)]
    if errs:
        failures.append(f"{len(errs)} schema violations: {errs[:5]}")
    log.info("schema validation", events=len(obs.tracer.events),
             errors=len(errs))

    rec = obs.reconcile(ledger)
    if not rec["exact"]:
        bad = {k: v for k, v in rec["fields"].items() if not v["equal"]}
        failures.append(f"mirror ledger not bit-exact: {bad}")
    log.info("ledger reconciliation", exact=rec["exact"])

    s = summarize(obs.tracer.events)
    checks = [("gs_comm", s["gs_comm"], ledger.gs_count),
              ("train_j", s["train_j"], ledger.train_energy_j),
              ("gs_j", s["gs_j"], ledger.gs_energy_j),
              ("lisl_j", s["lisl_j"], ledger.lisl_energy_j),
              ("wait_s", s["wait_s"], ledger.waiting_time_s)]
    for name, got, want in checks:
        if got != want:
            failures.append(f"report.{name}: trace {got!r} != "
                            f"ledger {want!r}")
    log.info("report-vs-ledger columns",
             ok=sum(g == w for _, g, w in checks), of=len(checks))

    obs.tracer.to_chrome_trace(os.path.join(args.out, "trace.json"))
    obs.metrics.to_json(os.path.join(args.out, "metrics.json"))
    table = render([jsonl])
    with open(os.path.join(args.out, "report.txt"), "w") as f:
        f.write(table + "\n")
    log.raw(table)

    if failures:
        for f in failures:
            log.warn(f)
        return 1
    log.info("obs-smoke PASS", artifacts=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
