"""Counter / gauge / histogram registry for round-engine metrics.

Labels are free-form keyword arguments; each (name, label-set) pair is an
independent series. Insertion order is preserved, which matters for the
ledger-reconciliation guarantee: a series accumulated in event order
replays the exact float-addition sequence the ``EnergyLedger`` performed,
so totals reconcile bit-for-bit, not approximately (see
observer.TracingObserver and DESIGN.md §10).

``total(name, **filter)`` sums matching series in insertion order with a
plain running ``+=`` — again the ledger's own accumulation scheme — so a
single-source decomposition (e.g. ``train_joules`` per round x cluster)
sums back to the ledger field exactly.
"""
from __future__ import annotations

import json
from typing import Optional


def _key(name: str, labels: dict) -> tuple:
    return (name,) + tuple(sorted(labels.items()))


class Metrics:
    """Minimal multi-series registry: counters, gauges, histograms."""

    def __init__(self):
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, list[float]] = {}

    # -- instruments ---------------------------------------------------------
    def count(self, name: str, value: float = 1.0, **labels) -> None:
        k = _key(name, labels)
        self._counters[k] = self._counters.get(k, 0.0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        self._gauges[_key(name, labels)] = value

    def observe(self, name: str, value: float, **labels) -> None:
        self._hists.setdefault(_key(name, labels), []).append(float(value))

    # -- reads ---------------------------------------------------------------
    def get(self, name: str, default: float = 0.0, **labels) -> float:
        return self._counters.get(_key(name, labels), default)

    def series(self, name: str, **label_filter):
        """[(labels_dict, value)] for every counter series of ``name``
        whose labels are a superset of ``label_filter``, insertion order."""
        out = []
        for k, v in self._counters.items():
            if k[0] != name:
                continue
            labels = dict(k[1:])
            if all(labels.get(f) == fv for f, fv in label_filter.items()):
                out.append((labels, v))
        return out

    def total(self, name: str, **label_filter) -> float:
        """In-order running sum over matching series (see module doc)."""
        tot = 0.0
        for _, v in self.series(name, **label_filter):
            tot += v
        return tot

    def values(self, name: str, **label_filter) -> list[float]:
        """Concatenated histogram observations across matching series."""
        out: list[float] = []
        for k, vs in self._hists.items():
            if k[0] != name:
                continue
            labels = dict(k[1:])
            if all(labels.get(f) == fv for f, fv in label_filter.items()):
                out.extend(vs)
        return out

    def histogram(self, name: str, bins: int = 10,
                  **label_filter) -> list[tuple[float, float, int]]:
        """Equal-width (lo, hi, count) bins over matching observations."""
        vs = self.values(name, **label_filter)
        if not vs:
            return []
        lo, hi = min(vs), max(vs)
        width = (hi - lo) / bins or 1.0
        counts = [0] * bins
        for v in vs:
            counts[min(int((v - lo) / width), bins - 1)] += 1
        return [(lo + i * width, lo + (i + 1) * width, c)
                for i, c in enumerate(counts)]

    # -- export --------------------------------------------------------------
    def to_dict(self) -> dict:
        return {"counters": self._group(self._counters),
                "gauges": self._group(self._gauges),
                "histograms": {name: [{"labels": dict(k[1:]), "values": v}
                                      for k, v in self._hists.items()
                                      if k[0] == name]
                               for name in {k[0] for k in self._hists}}}

    @staticmethod
    def _group(d: dict) -> dict:
        out: dict[str, list] = {}
        for k, v in d.items():
            out.setdefault(k[0], []).append({"labels": dict(k[1:]),
                                             "value": v})
        return out

    def to_json(self, path: Optional[str] = None) -> str:
        s = json.dumps(self.to_dict(), indent=1, sort_keys=True,
                       default=float)
        if path is not None:
            with open(path, "w") as f:
                f.write(s + "\n")
        return s
