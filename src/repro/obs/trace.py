"""Hierarchical span tracer with a dual timeline (DESIGN.md §10).

Every event carries BOTH clocks of a federated-constellation run:

* **host** — wall seconds since the tracer started (``time.perf_counter``);
  where the Python/XLA time of this process actually went.
* **sim**  — the simulated-constellation clock the ``EnergyLedger`` /
  ``WindowTable`` accounting advances (seconds since session t0); where
  the *satellites'* time went.

Events are appended to an in-memory list and (optionally) streamed to a
JSONL file, one event per line, so a crashed run still leaves a readable
trace. The JSONL schema is versioned (``TRACE_SCHEMA_VERSION``); CI's
``obs-smoke`` job validates every emitted event with ``validate_event``.

``to_chrome_trace`` renders the collected events into a Chrome
trace-event file (load in Perfetto / chrome://tracing): the **sim**
timeline is pid 1 with one track per training cluster plus a GS track,
the **host** timeline is pid 2 with the engine's phase spans. Sim seconds
map to trace microseconds (1 sim second -> 1 display second).
"""
from __future__ import annotations

import json
import time
from typing import IO, Optional

TRACE_SCHEMA_VERSION = 1

# kind -> {field: type-or-tuple-of-types}. ``None`` values are allowed for
# any field listed in _NULLABLE; extra fields are allowed everywhere (the
# schema is open — readers must ignore unknown fields).
_NUM = (int, float)
SCHEMA: dict[str, dict[str, tuple]] = {
    "session_start": {"algo": (str,), "n_clusters": (int,), "sim_t": _NUM},
    "round_start": {"round": (int,), "sim_t": _NUM},
    "select": {"round": (int,), "cluster": (int,), "engaged": (int,),
               "trained": (int,), "skipped": (int,)},
    "train": {"round": (int,), "cluster": (int,), "energy_j": _NUM,
              "barrier_s": _NUM, "sim_t0": _NUM},
    "comm": {"link": (str,), "n": (int,), "bits": _NUM, "energy_j": _NUM,
             "time_s": _NUM, "phase": (str,), "round": (int,),
             "cluster": (int,)},
    "wait": {"seconds": _NUM, "cause": (str,), "round": (int,),
             "cluster": (int,)},
    "phase": {"name": (str,), "round": (int,), "host_dur": _NUM,
              "sim_t0": _NUM, "sim_dur": _NUM},
    "straggler": {"round": (int,), "cluster": (int,), "action": (str,)},
    "async_merge": {"round": (int,), "cluster": (int,), "rank": (int,),
                    "alpha": _NUM},
    "note": {"name": (str,)},
    "sim_event": {"etype": (str,), "sim_t": _NUM, "seq": (int,),
                  "round": (int,), "cluster": (int,), "sat": (int,)},
    "fault": {"fkind": (str,), "sim_t": _NUM, "round": (int,),
              "cluster": (int,), "sat": (int,)},
    "recovery": {"action": (str,), "sim_t": _NUM, "round": (int,),
                 "cluster": (int,), "sat": (int,)},
    "robust_reject": {"reason": (str,), "round": (int,),
                      "cluster": (int,)},
    "quorum": {"frac": _NUM, "ok": (int,), "round": (int,),
               "cluster": (int,)},
    "round_end": {"round": (int,), "sim_t": _NUM, "sim_dur": _NUM,
                  "host_dur": _NUM},
    "session_end": {"sim_t": _NUM, "ledger": (dict,)},
}
_NULLABLE = {"round", "cluster", "sat", "sim_t0", "sim_dur"}
_COMM_LINKS = ("gs", "intra", "inter")


def validate_event(ev: dict) -> list[str]:
    """Schema errors for one event dict (empty list == valid)."""
    errs = []
    if not isinstance(ev, dict):
        return [f"event is {type(ev).__name__}, not dict"]
    if ev.get("v") != TRACE_SCHEMA_VERSION:
        errs.append(f"bad schema version {ev.get('v')!r}")
    kind = ev.get("kind")
    if kind not in SCHEMA:
        return errs + [f"unknown kind {kind!r}"]
    if not isinstance(ev.get("t_host"), _NUM):
        errs.append("missing/non-numeric t_host")
    for f, types in SCHEMA[kind].items():
        v = ev.get(f, None)
        if v is None:
            if f in _NULLABLE:
                continue
            errs.append(f"{kind}: missing field {f!r}")
        elif not isinstance(v, types) or isinstance(v, bool):
            errs.append(f"{kind}.{f}: {type(v).__name__} not in "
                        f"{[t.__name__ for t in types]}")
    if kind == "comm" and ev.get("link") not in _COMM_LINKS:
        errs.append(f"comm.link {ev.get('link')!r} not in {_COMM_LINKS}")
    return errs


class SpanTracer:
    """Collects schema'd events; streams JSONL; renders Chrome traces.

    ``emit`` stamps ``v`` and ``t_host`` (host seconds since tracer
    start) on every event. Imperative span pairs (``begin_span`` /
    ``end_span``) measure host duration across calls, for callers that
    cannot hold a context manager open (the engine's phase hooks).
    """

    def __init__(self, jsonl_path: Optional[str] = None):
        self.events: list[dict] = []
        self._t0 = time.perf_counter()
        self._fh: Optional[IO] = None
        self.jsonl_path = jsonl_path
        if jsonl_path is not None:
            self._fh = open(jsonl_path, "w")
        self._open: dict[tuple, float] = {}   # (name, key) -> host_t0

    # -- events --------------------------------------------------------------
    def now(self) -> float:
        return time.perf_counter() - self._t0

    def emit(self, kind: str, **fields) -> dict:
        ev = {"v": TRACE_SCHEMA_VERSION, "kind": kind,
              "t_host": self.now(), **fields}
        self.events.append(ev)
        if self._fh is not None:
            self._fh.write(json.dumps(ev, default=float) + "\n")
        return ev

    def begin_span(self, name: str, key=None) -> None:
        self._open[(name, key)] = self.now()

    def end_span(self, name: str, key=None, **fields) -> dict:
        t0 = self._open.pop((name, key), self.now())
        return self.emit("phase", name=name, host_dur=self.now() - t0,
                         **fields)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- Chrome trace-event export -------------------------------------------
    @staticmethod
    def _track(ev: dict) -> str:
        kc = ev.get("cluster")
        if ev.get("link") == "gs" or (ev.get("kind") == "wait"
                                      and kc is None):
            return "GS"
        return "GS" if kc is None else f"cluster{kc}"

    def chrome_events(self) -> list[dict]:
        """Trace-event list: pid 1 = sim timeline (per-cluster + GS
        tracks), pid 2 = host timeline (engine phases/rounds)."""
        out = []
        tids: dict[tuple, int] = {}

        def tid(pid, track):
            k = (pid, track)
            if k not in tids:
                tids[k] = len([t for t in tids if t[0] == pid]) + 1
                out.append({"ph": "M", "pid": pid, "tid": tids[k],
                            "name": "thread_name",
                            "args": {"name": track}})
            return tids[k]

        for pid, name in ((1, "sim timeline"), (2, "host timeline")):
            out.append({"ph": "M", "pid": pid, "tid": 0,
                        "name": "process_name", "args": {"name": name}})
        for ev in self.events:
            kind = ev["kind"]
            if kind == "train":
                out.append({
                    "ph": "X", "pid": 1,
                    "tid": tid(1, f"cluster{ev['cluster']}"),
                    "name": "train", "ts": ev["sim_t0"] * 1e6,
                    "dur": max(ev["barrier_s"], 1e-9) * 1e6,
                    "args": {"round": ev["round"],
                             "energy_j": ev["energy_j"]}})
            elif kind == "comm":
                out.append({
                    "ph": "i", "pid": 1, "tid": tid(1, self._track(ev)),
                    "name": f"{ev['link']} x{ev['n']}", "s": "t",
                    "ts": (ev.get("sim_t0") or 0.0) * 1e6,
                    "args": {k: ev[k] for k in
                             ("energy_j", "time_s", "bits", "phase")}})
            elif kind == "round_end":
                out.append({
                    "ph": "X", "pid": 1, "tid": tid(1, "rounds"),
                    "name": f"round {ev['round']}",
                    "ts": (ev["sim_t"] - ev["sim_dur"]) * 1e6,
                    "dur": max(ev["sim_dur"], 1e-9) * 1e6, "args": {}})
            elif kind == "sim_event":
                et = ev["etype"]
                if et == "contact_open" and "close_t" in ev:
                    # one span per GS pass, anchored at the open event
                    # (its payload carries the true close time); the
                    # matching contact_close event is subsumed
                    out.append({
                        "ph": "X", "pid": 1, "tid": tid(1, "GS contacts"),
                        "name": f"sat {ev.get('sat')}",
                        "ts": ev["sim_t"] * 1e6,
                        "dur": max(ev["close_t"] - ev["sim_t"],
                                   1e-9) * 1e6,
                        "args": {"cluster": ev.get("cluster")}})
                elif et != "contact_close":
                    kc = ev.get("cluster")
                    out.append({
                        "ph": "i", "pid": 1,
                        "tid": tid(1, "GS" if kc is None
                                   else f"cluster{kc}"),
                        "name": et, "s": "t", "ts": ev["sim_t"] * 1e6,
                        "args": {"seq": ev.get("seq"),
                                 "round": ev.get("round")}})
            elif kind in ("fault", "recovery"):
                # fault timeline: one sim-side track for the whole
                # campaign — faults and the recovery actions they
                # triggered interleave at their true sim times
                out.append({
                    "ph": "i", "pid": 1, "tid": tid(1, "faults"),
                    "name": ev.get("fkind") or ev.get("action"),
                    "s": "t", "ts": ev["sim_t"] * 1e6,
                    "args": {k: v for k, v in ev.items()
                             if k not in ("v", "kind", "t_host")}})
            elif kind in ("robust_reject", "quorum"):
                # value-layer robustness timeline: instants on one
                # "robust" track (no sim_t of their own — merges land at
                # the round boundary, so anchor at the host clock's
                # trace position via the round_start convention: use 0
                # when no round context exists)
                if kind == "quorum" and ev.get("ok"):
                    continue          # only degraded verdicts plot
                name = (ev.get("reason") if kind == "robust_reject"
                        else f"quorum degraded c{ev.get('cluster')}")
                out.append({
                    "ph": "i", "pid": 1, "tid": tid(1, "robust"),
                    "name": name, "s": "t",
                    "ts": ev["t_host"] * 1e6,
                    "args": {k: v for k, v in ev.items()
                             if k not in ("v", "kind", "t_host")}})
            elif kind == "phase":
                out.append({
                    "ph": "X", "pid": 2, "tid": tid(2, "engine"),
                    "name": ev["name"], "ts": (ev["t_host"]
                                               - ev["host_dur"]) * 1e6,
                    "dur": max(ev["host_dur"], 1e-9) * 1e6,
                    "args": {"round": ev.get("round"),
                             "sim_dur": ev.get("sim_dur")}})
        return out

    def to_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.chrome_events(),
                       "displayTimeUnit": "ms"}, f, default=float)
        return path


def load_events(path: str) -> list[dict]:
    """Read a trace JSONL file back into event dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
