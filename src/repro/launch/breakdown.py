"""Attribution tool for §Perf: where do the collective / memory bytes of a
compiled dry-run cell come from?

    PYTHONPATH=src python -m repro.launch.breakdown results/hlo/<cell>.hlo

Groups execution-count-weighted collective bytes by (kind, op_name metadata
prefix) and memory bytes by computation, so each hillclimb hypothesis can
be checked against the actual dominant source.
"""
from __future__ import annotations

import re
import sys
from collections import defaultdict

from repro.launch.hlo_cost import (_COLLECTIVES, _call_edges, _comp_cost,
                                   _fusion_out_bytes, _fusion_param_bytes,
                                   _instr_bytes, _shape_bytes, _SKIP_OPS,
                                   parse_hlo)


def _counts(comps, entry):
    counts = {c: 0.0 for c in comps}

    def visit(name, mult, seen):
        if name in seen:
            return
        counts[name] += mult
        for callee, w in _call_edges(comps[name], comps):
            visit(callee, mult * w, seen + (name,))

    visit(entry, 1.0, ())
    return counts


def _opname(line: str) -> str:
    m = re.search(r'op_name="([^"]*)"', line)
    if not m:
        return "(none)"
    name = m.group(1)
    # keep the semantic tail: jit(step)/jvp()/while/body/...  -> last 2 parts
    parts = [p for p in name.split("/") if p and not p.startswith("jit(")]
    return "/".join(parts[-3:]) if parts else name[:60]


def breakdown(path: str, top: int = 15):
    text = open(path).read()
    comps, entry = parse_hlo(text)
    counts = _counts(comps, entry)
    fusion_names = set()
    for comp in comps.values():
        for inst in comp.instrs:
            if inst.op == "fusion":
                m = re.search(r"calls=(%[\w.\-]+)", inst.line)
                if m:
                    fusion_names.add(m.group(1))
    fp = {n: _fusion_param_bytes(comps[n]) for n in fusion_names if n in comps}
    fo = {n: _fusion_out_bytes(comps[n]) for n in fusion_names if n in comps}

    coll = defaultdict(float)
    mem = defaultdict(float)
    for name, comp in comps.items():
        c = counts[name]
        if c == 0:
            continue
        for inst in comp.instrs:
            base = inst.op[:-6] if inst.op.endswith("-start") else inst.op
            if base in _COLLECTIVES:
                rb = _shape_bytes(inst.result_type)
                mult = 2.0 if base == "all-reduce" else 1.0
                coll[(base, _opname(inst.line))] += c * rb * mult
            if name not in fusion_names and inst.op not in _SKIP_OPS:
                b = _instr_bytes(inst, comp, fp, fo)
                if b:
                    mem[(inst.op, _opname(inst.line))] += c * b

    print(f"== {path}")
    print(f"-- collective bytes by (kind, op_name), per device, top {top}:")
    tot = sum(coll.values())
    for (k, o), v in sorted(coll.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {v:12.3e} ({v/max(tot,1e-9)*100:5.1f}%) {k:20s} {o}")
    print(f"  total: {tot:.3e} B/device -> t_coll {tot/50e9:.3f}s")
    print(f"-- memory bytes by (op, op_name), per device, top {top}:")
    tot = sum(mem.values())
    for (k, o), v in sorted(mem.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {v:12.3e} ({v/max(tot,1e-9)*100:5.1f}%) {k:20s} {o}")
    print(f"  total: {tot:.3e} B/device -> t_mem {tot/819e9:.3f}s")


if __name__ == "__main__":
    for p in sys.argv[1:]:
        breakdown(p)
