"""Attribution tool for §Perf: where do the collective / memory bytes of a
compiled dry-run cell come from?

    PYTHONPATH=src python -m repro.launch.breakdown results/hlo/<cell>.hlo

Groups execution-count-weighted collective bytes by (kind, op_name metadata
prefix) and memory bytes by computation, so each hillclimb hypothesis can
be checked against the actual dominant source.

``analyze(text)`` is the pure core (HLO text in, attribution dict out);
``breakdown(path)`` renders it. tests/test_breakdown.py pins the analysis
on a small synthetic-HLO golden.
"""
from __future__ import annotations

import re
import sys
from collections import defaultdict

from repro.launch.hlo_cost import (_COLLECTIVES, _call_edges,
                                   _fusion_out_bytes, _fusion_param_bytes,
                                   _instr_bytes, _shape_bytes, _SKIP_OPS,
                                   parse_hlo)
from repro.obs import get_logger

log = get_logger("launch.breakdown")

# per-device bandwidth assumptions used for the printed time estimates
COLL_BW = 50e9     # B/s interconnect
MEM_BW = 819e9     # B/s HBM


def _counts(comps, entry):
    counts = {c: 0.0 for c in comps}

    def visit(name, mult, seen):
        if name in seen:
            return
        counts[name] += mult
        for callee, w in _call_edges(comps[name], comps):
            visit(callee, mult * w, seen + (name,))

    visit(entry, 1.0, ())
    return counts


def _opname(line: str) -> str:
    m = re.search(r'op_name="([^"]*)"', line)
    if not m:
        return "(none)"
    name = m.group(1)
    # keep the semantic tail: jit(step)/jvp()/while/body/...  -> last 2 parts
    parts = [p for p in name.split("/") if p and not p.startswith("jit(")]
    return "/".join(parts[-3:]) if parts else name[:60]


def analyze(text: str) -> dict:
    """Execution-count-weighted byte attribution over HLO text.

    Returns ``{"collective": {(kind, op_name): bytes},
    "memory": {(op, op_name): bytes}, "collective_total": float,
    "memory_total": float, "t_coll_s": float, "t_mem_s": float}`` —
    all per device."""
    comps, entry = parse_hlo(text)
    coll: dict = defaultdict(float)
    mem: dict = defaultdict(float)
    if entry:
        counts = _counts(comps, entry)
        fusion_names = set()
        for comp in comps.values():
            for inst in comp.instrs:
                if inst.op == "fusion":
                    m = re.search(r"calls=(%[\w.\-]+)", inst.line)
                    if m:
                        fusion_names.add(m.group(1))
        fp = {n: _fusion_param_bytes(comps[n]) for n in fusion_names
              if n in comps}
        fo = {n: _fusion_out_bytes(comps[n]) for n in fusion_names
              if n in comps}
        for name, comp in comps.items():
            c = counts[name]
            if c == 0:
                continue
            for inst in comp.instrs:
                base = (inst.op[:-6] if inst.op.endswith("-start")
                        else inst.op)
                if base in _COLLECTIVES:
                    rb = _shape_bytes(inst.result_type)
                    mult = 2.0 if base == "all-reduce" else 1.0
                    coll[(base, _opname(inst.line))] += c * rb * mult
                if name not in fusion_names and inst.op not in _SKIP_OPS:
                    b = _instr_bytes(inst, comp, fp, fo)
                    if b:
                        mem[(inst.op, _opname(inst.line))] += c * b
    coll_tot = sum(coll.values())
    mem_tot = sum(mem.values())
    return {"collective": dict(coll), "memory": dict(mem),
            "collective_total": coll_tot, "memory_total": mem_tot,
            "t_coll_s": coll_tot / COLL_BW, "t_mem_s": mem_tot / MEM_BW}


def breakdown(path: str, top: int = 15) -> dict:
    res = analyze(open(path).read())
    log.raw(f"== {path}")
    log.raw(f"-- collective bytes by (kind, op_name), per device, "
            f"top {top}:")
    tot = res["collective_total"]
    for (k, o), v in sorted(res["collective"].items(),
                            key=lambda kv: -kv[1])[:top]:
        log.raw(f"  {v:12.3e} ({v/max(tot,1e-9)*100:5.1f}%) {k:20s} {o}")
    log.raw(f"  total: {tot:.3e} B/device -> t_coll {res['t_coll_s']:.3f}s")
    log.raw(f"-- memory bytes by (op, op_name), per device, top {top}:")
    tot = res["memory_total"]
    for (k, o), v in sorted(res["memory"].items(),
                            key=lambda kv: -kv[1])[:top]:
        log.raw(f"  {v:12.3e} ({v/max(tot,1e-9)*100:5.1f}%) {k:20s} {o}")
    log.raw(f"  total: {tot:.3e} B/device -> t_mem {res['t_mem_s']:.3f}s")
    return res


if __name__ == "__main__":
    for p in sys.argv[1:]:
        breakdown(p)
