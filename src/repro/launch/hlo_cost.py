"""While-aware cost model over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — `while` loop
bodies (every ``lax.scan``: the layer stack, chunked attention, chunked CE,
SSM scans) are counted a single time regardless of trip count, so FLOPs /
bytes / collective bytes are all undercounted by the loop trip counts.

This parser rebuilds the call graph from the HLO text and weights every
computation by its execution count:

  * ``while(...)`` bodies/conditions x trip count — recovered from the
    loop-bound ``constant(N)`` + ``compare(..), direction=LT`` in the
    condition computation (the shape lax.scan lowers to).
  * ``fusion(...), calls=%c`` and ``call``/``to_apply`` x 1.
  * conditional branches x 1 (upper bound).

Per computation it counts:
  * dot FLOPs: 2 * |result| * prod(lhs contracting dims)  (MXU work)
  * bytes: result + operand bytes of every top-level instruction
    (post-fusion HLO: one HBM write per instruction output, one read per
    operand — fusion internals excluded)
  * collective bytes by kind, with ring-traffic multipliers
    (all-reduce 2x result, reduce-scatter = operand bytes, others =
    result bytes).

All quantities are PER-PARTITION (the HLO is the SPMD-partitioned module);
multiply by chip count for globals.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_NAME_RE = re.compile(r"%[\w.\-]+")
_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "domain"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(text: str):
    """All (dtype, elems) shapes in a type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _shape_bytes(text: str) -> int:
    return sum(_DTYPE_BYTES[dt] * n for dt, n in _shape_list(text))


@dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


_COMP_HDR = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],:{}/* ]+?))\s+"
    r"([\w\-]+)\((.*)$")


def parse_hlo(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = ""
    for raw in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw)
        m = _COMP_HDR.match(line.strip())
        if m:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rtype, op, rest = mi.groups()
        # operands: names inside the first balanced paren chunk
        depth, i, args = 1, 0, ""
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = rest[:i]
                    break
        operands = _NAME_RE.findall(args)
        inst = Instr(name, rtype, op, operands, line)
        cur.instrs.append(inst)
        cur.by_name[name] = inst
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """lax.scan conditions: compare(induction, constant(N)), direction=LT."""
    consts = {}
    for inst in cond.instrs:
        if inst.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", inst.line)
            if m:
                consts[inst.name] = int(m.group(1))
    for inst in cond.instrs:
        if inst.op == "compare" and "direction=LT" in inst.line:
            for o in inst.operands:
                if o in consts and consts[o] > 0:
                    return consts[o]
    pos = [v for v in consts.values() if v > 0]
    return max(pos) if pos else 1


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})


def _dot_flops(inst: Instr, comp: Computation) -> float:
    res = _shape_list(inst.result_type)
    n_out = sum(n for _, n in res)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    if not m or not inst.operands:
        return 2.0 * n_out
    lhs = comp.by_name.get(inst.operands[0])
    if lhs is None:
        return 2.0 * n_out
    lhs_shapes = _SHAPE_RE.findall(lhs.result_type)
    if not lhs_shapes:
        return 2.0 * n_out
    dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
    k = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(dims):
            k *= dims[int(ci)]
    return 2.0 * n_out * k


_NO_BYTES_OPS = {"while", "conditional", "call"}


def _fusion_param_bytes(comp: Computation) -> dict[str, int]:
    """Effective read bytes per parameter of a fusion computation.

    XLA fuses (dynamic-)slice into consumers and dynamic-update-slice into
    producers:
      * a param used only by slices is read at slice size;
      * a param used only as the BASE of dynamic-update-slice is aliased
        in place — zero read traffic."""
    uses: dict[str, list[Instr]] = {}
    for inst in comp.instrs:
        for o in inst.operands:
            uses.setdefault(o, []).append(inst)
    out = {}
    for inst in comp.instrs:
        if inst.op != "parameter":
            continue
        full = _shape_bytes(inst.result_type)
        us = uses.get(inst.name, [])
        if us and all(u.op in ("dynamic-slice", "slice") and
                      u.operands and u.operands[0] == inst.name for u in us):
            full = sum(_shape_bytes(u.result_type) for u in us)
        elif us and all(u.op == "dynamic-update-slice" and
                        u.operands and u.operands[0] == inst.name
                        for u in us):
            full = 0
        out[inst.name] = full
    return out


def _fusion_out_bytes(comp: Computation) -> int:
    """Effective write bytes of a fusion: a dynamic-update-slice root only
    writes the update slice (the base aliases in place)."""
    root = None
    for inst in comp.instrs:
        if "ROOT" in inst.line:
            root = inst
    if root is None:
        root = comp.instrs[-1] if comp.instrs else None
    if root is None:
        return 0
    # walk through trivial wrappers to find a DUS
    seen, cur = set(), root
    while cur is not None and cur.name not in seen:
        seen.add(cur.name)
        if cur.op == "dynamic-update-slice":
            if len(cur.operands) >= 2:
                upd = comp.by_name.get(cur.operands[1])
                if upd is not None:
                    return _shape_bytes(upd.result_type)
            return _shape_bytes(cur.result_type)
        if cur.op in ("bitcast", "copy", "tuple") and cur.operands:
            cur = comp.by_name.get(cur.operands[0])
        else:
            break
    return _shape_bytes(root.result_type)


def _instr_bytes(inst: Instr, comp: Computation,
                 fusion_params: dict[str, dict[str, int]],
                 fusion_outs: dict[str, int]) -> float:
    """HBM traffic estimate for one top-level instruction."""
    if inst.op in _NO_BYTES_OPS:
        return 0.0
    if inst.op == "dynamic-slice" or inst.op == "slice":
        return 2.0 * _shape_bytes(inst.result_type)        # read + write slice
    if inst.op == "dynamic-update-slice":
        upd = 0
        if len(inst.operands) >= 2:
            src = comp.by_name.get(inst.operands[1])
            if src is not None:
                upd = _shape_bytes(src.result_type)
        return 2.0 * (upd or _shape_bytes(inst.result_type))
    b = float(_shape_bytes(inst.result_type))
    if inst.op == "fusion":
        m = re.search(r"calls=(%[\w.\-]+)", inst.line)
        fname = m.group(1) if m else None
        eff = fusion_params.get(fname, {})
        eff_list = list(eff.values())
        out_eff = fusion_outs.get(fname)
        b = float(out_eff if out_eff is not None
                  else _shape_bytes(inst.result_type))
        for idx, o in enumerate(inst.operands):
            src = comp.by_name.get(o)
            if src is None or src.op == "constant":
                continue
            b += (eff_list[idx] if idx < len(eff_list)
                  else _shape_bytes(src.result_type))
        return b
    for o in inst.operands:
        src = comp.by_name.get(o)
        if src is not None and src.op not in ("constant",):
            b += _shape_bytes(src.result_type)
    return b


def _comp_cost(comp: Computation,
               fusion_params: dict[str, dict[str, int]],
               fusion_outs: dict[str, int]) -> CostTotals:
    t = CostTotals()
    for inst in comp.instrs:
        if inst.op in _SKIP_OPS:
            continue
        if inst.op in ("dot",):
            t.flops += _dot_flops(inst, comp)
        kind = None
        base = inst.op[:-6] if inst.op.endswith("-start") else inst.op
        if base in _COLLECTIVES:
            kind = base
        if kind:
            rb = _shape_bytes(inst.result_type)
            if kind == "all-reduce":
                t.coll[kind] += 2.0 * rb
            elif kind == "reduce-scatter":
                ob = sum(_shape_bytes(comp.by_name[o].result_type)
                         for o in inst.operands if o in comp.by_name)
                t.coll[kind] += float(ob or rb)
            else:
                t.coll[kind] += float(rb)
        if inst.op.endswith("-done"):
            continue
        t.bytes += _instr_bytes(inst, comp, fusion_params, fusion_outs)
    return t


def _call_edges(comp: Computation, comps: dict) -> list[tuple[str, float]]:
    edges = []
    for inst in comp.instrs:
        if inst.op == "while":
            mb = re.search(r"body=(%[\w.\-]+)", inst.line)
            mc = re.search(r"condition=(%[\w.\-]+)", inst.line)
            trips = _trip_count(comps[mc.group(1)]) if mc and \
                mc.group(1) in comps else 1
            if mb and mb.group(1) in comps:
                edges.append((mb.group(1), float(max(trips, 1))))
        elif inst.op == "fusion":
            m = re.search(r"calls=(%[\w.\-]+)", inst.line)
            if m and m.group(1) in comps:
                edges.append((m.group(1), 1.0))
        elif inst.op in ("call", "custom-call"):
            m = re.search(r"to_apply=(%[\w.\-]+)", inst.line)
            if m and m.group(1) in comps:
                edges.append((m.group(1), 1.0))
        elif inst.op == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"true_computation=(%[\w.\-]+)|"
                                 r"false_computation=(%[\w.\-]+))", inst.line):
                for g in m.groups():
                    if g:
                        for name in _NAME_RE.findall(g) or [g]:
                            if name in comps:
                                edges.append((name, 1.0))
    return edges


def analyze_hlo(text: str) -> CostTotals:
    """Execution-count-weighted totals for the whole module (per device).

    Fusion computations contribute their dot FLOPs but not their internal
    byte traffic (inputs/outputs are counted at the call site)."""
    comps, entry = parse_hlo(text)
    if not entry:
        return CostTotals()

    counts: dict[str, float] = {c: 0.0 for c in comps}

    def visit(name: str, mult: float, seen: tuple):
        if name in seen:            # defensive: HLO has no recursion
            return
        counts[name] += mult
        for callee, w in _call_edges(comps[name], comps):
            visit(callee, mult * w, seen + (name,))

    visit(entry, 1.0, ())

    total = CostTotals()
    fusion_names = set()
    for comp in comps.values():
        for inst in comp.instrs:
            if inst.op == "fusion":
                m = re.search(r"calls=(%[\w.\-]+)", inst.line)
                if m:
                    fusion_names.add(m.group(1))
    fusion_params = {name: _fusion_param_bytes(comps[name])
                     for name in fusion_names if name in comps}
    fusion_outs = {name: _fusion_out_bytes(comps[name])
                   for name in fusion_names if name in comps}
    for name, comp in comps.items():
        c = counts[name]
        if c == 0:
            continue
        t = _comp_cost(comp, fusion_params, fusion_outs)
        total.flops += c * t.flops
        for k, v in t.coll.items():
            total.coll[k] += c * v
        if name not in fusion_names:
            total.bytes += c * t.bytes
    return total
