"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
        --shape train_4k [--multi-pod] [--all] [--json out.jsonl]

The XLA_FLAGS lines below MUST run before any jax import (jax locks the
device count on first init); nothing else in the repo sets it globally.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, ShapeConfig, cell_is_runnable,
                                get_config, input_specs)
from repro.dist.sharding import (batch_specs, cache_specs_sharding,
                                 data_axes, param_specs)
from repro.launch import steps as S
from repro.launch.analysis import Roofline, analyse
from repro.launch.mesh import make_production_mesh
from repro.models import api

K_CLUSTERS = 2          # FL clusters on the multi-pod mesh (= #pods)


def _sds(tree, f):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(f(s.shape), s.dtype),
                        tree)


def _with_leading(tree, k: int):
    return _sds(tree, lambda shp: (k,) + tuple(shp))


def _clustered_batch(specs: dict[str, Any], k: int) -> dict[str, Any]:
    """(B, ...) -> (K, B/K, ...); mrope position_ids (3,B,S) -> (K,3,B/K,S)."""
    out = {}
    for name, s in specs.items():
        shp = list(s.shape)
        bdim = 1 if name == "position_ids" else 0
        assert shp[bdim] % k == 0, (name, shp)
        shp[bdim] //= k
        if name == "position_ids":
            shp = [k] + shp
        else:
            shp = [k] + shp
        out[name] = jax.ShapeDtypeStruct(tuple(shp), s.dtype)
    return out


def prepare_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool,
                 causal_skip: bool = False, remat: bool = True,
                 fsdp: bool = True, mix: bool = True, tp=None,
                 moe_groups: int = 0):
    """Returns (jitted_fn, arg_specs) ready to .lower(*arg_specs).

    tp: True/False forces tensor parallelism; None applies the per-arch
    policy — prefer_tp=False archs run pure-DP, but ONLY for single-pod
    training (decode/prefill batches are too small to spread over the
    whole chip count, and clustered multi-pod batches shard over the
    in-pod data axis only)."""
    cfg = get_config(arch)
    shape_ = SHAPES[shape_name]
    if tp is None:
        tp = not (not cfg.prefer_tp and shape_.kind == "train"
                  and not multi_pod)
    if moe_groups < 0:
        cfg = dataclasses.replace(cfg, moe_groups=0)     # force flat dispatch
    elif moe_groups:
        cfg = dataclasses.replace(cfg, moe_groups=moe_groups)
    elif cfg.moe_groups and multi_pod and shape_.kind == "train":
        # grouped dispatch REGRESSES under the pod-vmapped clustered step
        # (GSPMD partitions the nested-vmapped scatter by replication;
        # measured 800s vs 225s collective term on deepseek-v2 — see
        # EXPERIMENTS.md §Perf). Multi-pod FL training uses flat dispatch.
        cfg = dataclasses.replace(cfg, moe_groups=0)
    elif cfg.moe_groups:
        # dispatch groups must MATCH the width of the batch sharding
        # (16 groups on a 32-wide dp axis leaves the group dim unsharded —
        # measured 10x worse collectives on jamba-mp prefill, §Perf)
        dp_total = mesh.shape["data"]
        if not tp:
            dp_total *= mesh.shape["model"]
        if multi_pod and shape_.kind != "train":
            dp_total *= mesh.shape["pod"]
        cfg = dataclasses.replace(cfg, moe_groups=dp_total)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    ns = lambda spec: NamedSharding(mesh, spec)

    if shape.kind == "train":
        specs["weights"] = jax.ShapeDtypeStruct((shape.global_batch,),
                                                jnp.float32)
        params = api.param_specs(cfg)
        clustered = multi_pod
        if clustered:
            params = _with_leading(params, K_CLUSTERS)
            batch = _clustered_batch(specs, K_CLUSTERS)
        else:
            batch = specs
        mom = params
        p_spec = param_specs(params, mesh, cluster_dim=clustered, fsdp=fsdp,
                             cfg=cfg, tp=tp)
        b_spec = batch_specs(batch, mesh, cluster_dim=clustered, tp=tp)
        step = S.build_fl_train_step(cfg, mesh, clustered=clustered,
                                     causal_skip=causal_skip, remat=remat,
                                     mix=mix, tp=tp)
        p_sh = jax.tree.map(ns, p_spec)
        b_sh = jax.tree.map(ns, b_spec)
        if clustered:
            m_spec = jax.ShapeDtypeStruct((K_CLUSTERS, K_CLUSTERS), jnp.float32)
            fn = jax.jit(step,
                         in_shardings=(p_sh, p_sh, b_sh, ns(P())),
                         out_shardings=(p_sh, p_sh, ns(P())))
            args = (params, mom, batch, m_spec)
        else:
            fn = jax.jit(step, in_shardings=(p_sh, p_sh, b_sh),
                         out_shardings=(p_sh, p_sh, ns(P())))
            args = (params, mom, batch)
        tokens = shape.global_batch * shape.seq_len
        mflops = api.model_flops(cfg, tokens, "train")
        return fn, args, mflops

    params = api.param_specs(cfg)
    p_spec = param_specs(params, mesh, cluster_dim=False, fsdp=fsdp,
                         cfg=cfg, tp=tp)
    p_sh = jax.tree.map(ns, p_spec)

    if shape.kind == "prefill":
        b_spec = batch_specs(specs, mesh, tp=tp)
        b_sh = jax.tree.map(ns, b_spec)
        step = S.build_prefill_step(cfg, mesh, causal_skip=causal_skip, tp=tp)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=None)
        tokens = shape.global_batch * shape.seq_len
        mflops = api.model_flops(cfg, tokens, "prefill")
        return fn, (params, specs), mflops

    # decode
    cache = specs.pop("cache")
    b_spec = batch_specs(specs, mesh, tp=tp)
    c_spec = cache_specs_sharding(cache, mesh)
    b_sh = jax.tree.map(ns, b_spec)
    c_sh = jax.tree.map(ns, c_spec)
    step = S.build_decode_step(cfg, mesh, tp=tp)

    def step2(params, batch, cache):
        return step(params, {**batch, "cache": cache})

    fn = jax.jit(step2, in_shardings=(p_sh, b_sh, c_sh),
                 out_shardings=(None, c_sh))
    tokens = shape.global_batch          # one new token per sequence
    mflops = api.model_flops(cfg, tokens, "decode")
    return fn, (params, specs, cache), mflops


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True, save_hlo: str = None, **kw) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_runnable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        if verbose:
            print(f"SKIP  {arch} x {shape_name} [{mesh_name}]: {reason}")
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with mesh:
        fn, args, mflops = prepare_cell(arch, shape_name, mesh,
                                        multi_pod=multi_pod, **kw)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    if save_hlo:
        os.makedirs(save_hlo, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_name.replace('x', '-')}"
        with open(os.path.join(save_hlo, tag + ".hlo"), "w") as f:
            f.write(compiled.as_text())
    rl = analyse(compiled, lowered, arch=arch, shape=shape_name,
                 mesh_name=mesh_name, chips=chips, model_flops=mflops)
    row = rl.row()
    row.update({"status": "ok", "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1)})
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            row["memory_analysis"] = {
                k: int(getattr(ma, k, 0)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")}
    except Exception:
        pass
    if verbose:
        print(f"OK    {arch} x {shape_name} [{mesh_name}] "
              f"flops={row['flops']:.3e} bytes={row['bytes']:.3e} "
              f"coll={row['coll_bytes']:.3e} dom={row['dominant']} "
              f"frac={row['roofline_fraction']:.3f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all 40 cells")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-tp", action="store_true")
    ap.add_argument("--force-tp", action="store_true")
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--no-mix", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--json", default=os.path.join("results",
                                                   "dryrun.jsonl"),
                    help="append JSONL rows here (default: "
                         "results/dryrun.jsonl, where fl/engine/costs.py "
                         "resolves 'measured:' c_flop cells from; "
                         "--json '' disables)")
    ap.add_argument("--save-hlo", default=None, help="dir for compiled HLO text")
    args = ap.parse_args(argv)

    tp = None
    if args.no_tp:
        tp = False
    if args.force_tp:
        tp = True
    kw = dict(fsdp=not args.no_fsdp, remat=not args.no_remat,
              causal_skip=args.causal_skip, tp=tp,
              moe_groups=args.moe_groups, mix=not args.no_mix)
    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rows = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                rows.append(run_cell(arch, shape, multi_pod=mp,
                                     save_hlo=args.save_hlo, **kw))
            except Exception as e:
                print(f"FAIL  {arch} x {shape} "
                      f"[{'2x16x16' if mp else '16x16'}]: {type(e).__name__}: "
                      f"{str(e)[:300]}")
                rows.append({"arch": arch, "shape": shape,
                             "mesh": "2x16x16" if mp else "16x16",
                             "status": "fail", "error": str(e)[:500]})
            if args.json:
                d = os.path.dirname(args.json)
                if d:
                    os.makedirs(d, exist_ok=True)
                with open(args.json, "a") as f:
                    f.write(json.dumps(rows[-1]) + "\n")
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skip" for r in rows)
    n_fail = sum(r["status"] == "fail" for r in rows)
    print(f"\n{n_ok} ok / {n_skip} skip / {n_fail} fail")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
