"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs   / (chips * 197 TFLOP/s)
    memory term     = HLO_bytes   / (chips * 819 GB/s)
    collective term = coll_bytes  / (chips * 50 GB/s)

cost_analysis() gives FLOPs/bytes; collective bytes are parsed from the
compiled HLO text by summing the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128)\[([\d,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[tok_dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective kind over the (SPMD-partitioned)
    module. Start/done pairs are counted once (the -start form)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        kind = None
        for k in _COLLECTIVES:
            if rhs.startswith(k + "(") or rhs.startswith(k + "-start("):
                kind = k
                break
            # shape-prefixed form: "bf16[...] all-gather(...)"
            m = re.match(r"^[\w\[\],{}: ]*?\b" + k + r"(-start)?\(", rhs)
            if m:
                kind = k
                break
        if kind is None:
            continue
        if kind + "-done" in rhs:
            continue
        # result shapes live on the LHS (may be a tuple)
        total = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(lhs))
        if total == 0:   # fall back to operand shapes on the RHS
            total = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(rhs))
        out[kind] += total
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float
    bytes_accessed: float
    coll_bytes: dict[str, int]
    model_flops: float
    per_device_mem: float = 0.0

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.total_coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the step is to the compute roofline: the fraction of
        the bound time spent on useful model FLOPs."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return ideal / self.bound_time if self.bound_time else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": self.total_coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_mem_gb": self.per_device_mem / 1e9,
        }


def analyse(compiled, lowered, *, arch: str, shape: str, mesh_name: str,
            chips: int, model_flops: float) -> Roofline:
    """Roofline terms from the while-aware HLO cost model (hlo_cost.py);
    global quantities = per-partition totals x chips (uniform SPMD)."""
    from repro.launch.hlo_cost import analyze_hlo
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    tot = analyze_hlo(hlo)
    flops = tot.flops * chips
    bytes_accessed = tot.bytes * chips
    coll = {k: int(v * chips) for k, v in tot.coll.items()}
    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(getattr(ma, "temp_size_in_bytes", 0) +
                        getattr(ma, "argument_size_in_bytes", 0) +
                        getattr(ma, "output_size_in_bytes", 0) -
                        getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    flops=flops, bytes_accessed=bytes_accessed,
                    coll_bytes=coll, model_flops=model_flops,
                    per_device_mem=mem)
