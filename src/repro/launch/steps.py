"""Jit-able datacenter step functions (Level B of DESIGN.md §2).

``fl_train_step`` maps the CroSatFL hierarchy onto the production mesh:

  * cluster  = pod. Cluster models carry a leading K dim sharded over
    "pod" (each pod holds its own model; vmap(spmd_axis_name="pod")
    partitions the per-cluster computation with zero cross-pod traffic).
  * intra-cluster aggregation = the data-axis gradient all-reduce (ICI).
    Skip-One enters as per-example ``weights`` — a skipped client's batch
    shard is zero-weighted and the weighted mean renormalizes (Eq. 26).
  * random-k cross-aggregation = the (K, K) mixing einsum over the pod
    axis (DCN) — the only cross-pod collective, carrying
    |group|/K-sparse rows (Eq. 37).

Single-pod meshes have exactly one cluster: no leading K dim and no
mixing term (the mesh IS the cluster).

``prefill_step`` / ``decode_step`` serve the consolidated model (Eq. 38):
params sharded (FSDP x TP), batch over all data axes.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.ctx import use_rules
from repro.dist.sharding import activation_rules
from repro.models import api

F32 = jnp.float32


def _sgd(params, grads, mom, lr: float, momentum: float = 0.9):
    """Momentum SGD keeping state in the params dtype (memory: the giant
    archs hold momentum in bf16; DESIGN.md §6)."""
    def upd(p, g, m):
        m2 = (momentum * m.astype(F32) + g.astype(F32)).astype(m.dtype)
        return (p.astype(F32) - lr * m2.astype(F32)).astype(p.dtype), m2
    out = jax.tree.map(upd, params, grads, mom)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, new_m


def build_fl_train_step(cfg, mesh, *, clustered: bool, lr: float = 1e-2,
                        causal_skip: bool = False, remat: bool = True,
                        mix: bool = True, tp: bool = True):
    """Returns step(params, mom, batch[, mix_matrix]) -> (params', mom',
    loss). ``clustered``: leading K cluster dim on params/batch (multi-pod).
    """
    rules = activation_rules(mesh, cluster_vmapped=clustered, tp=tp)

    def loss_fn(params, batch):
        with use_rules(mesh, rules):
            return api.train_loss(params, batch, cfg, remat=remat,
                                  causal_skip=causal_skip)

    if not clustered:
        def step(params, mom, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_p, new_m = _sgd(params, grads, mom, lr)
            return new_p, new_m, loss
        return step

    grad_one = jax.value_and_grad(loss_fn)

    def step(params, mom, batch, mix_matrix):
        losses, grads = jax.vmap(grad_one, spmd_axis_name="pod")(params, batch)
        new_p, new_m = _sgd(params, grads, mom, lr)
        if mix:
            # Eq. 37 over the pod axis: w'_k = sum_j M[k, j] w_j
            def mix_leaf(x):
                return jnp.einsum("kj,j...->k...", mix_matrix.astype(F32),
                                  x.astype(F32)).astype(x.dtype)
            new_p = jax.tree.map(mix_leaf, new_p)
        return new_p, new_m, losses

    return step


def build_prefill_step(cfg, mesh, *, causal_skip: bool = False,
                       tp: bool = True):
    rules = activation_rules(mesh, tp=tp)

    def step(params, batch):
        with use_rules(mesh, rules):
            return api.prefill(params, batch, cfg, causal_skip=causal_skip)

    return step


def build_decode_step(cfg, mesh, *, tp: bool = True):
    rules = activation_rules(mesh, tp=tp)

    def step(params, batch):
        with use_rules(mesh, rules):
            return api.decode_step(params, batch, cfg)

    return step


def consolidate_step(cluster_params, n_samples):
    """Eq. 38 on the mesh: weighted average over the leading pod dim."""
    w = n_samples.astype(F32)
    w = w / w.sum()

    def avg(leaf):
        return jnp.einsum("k,k...->...", w, leaf.astype(F32)).astype(leaf.dtype)

    return jax.tree.map(avg, cluster_params)
