"""Production FL-training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        [--steps 100] [--test-mesh] [--reduced] [--ckpt-dir DIR] [--resume]

On a real TPU slice this builds the production mesh (16x16 per pod;
2x16x16 with --multi-pod), initializes the K cluster models SHARDED
(params never materialize on one host), and drives
``steps.build_fl_train_step`` — the exact function the dry-run compiles —
with Skip-One weight masks, per-round random-k mixing matrices, and
checkpointing at edge-round boundaries (restart-safe; see ckpt/).

On this CPU container use ``--test-mesh --reduced`` (tiny config, 1-device
mesh) — the code path is identical.
"""
import argparse
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.ckpt import load_pytree, save_pytree
from repro.configs.base import get_config
from repro.core import crossagg, skipone
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import api


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--test-mesh", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k-nbr", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_test_mesh(multi_pod=True) if args.test_mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    K = args.clusters
    print(f"arch={cfg.name} params={api.count_params(cfg)/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} K={K}")

    rng = np.random.default_rng(0)
    keys = jax.random.split(jax.random.PRNGKey(0), K)
    with mesh:
        params = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[api.init(cfg, k) for k in keys])
        mom = jax.tree.map(jnp.zeros_like, params)
        step = jax.jit(S.build_fl_train_step(cfg, mesh, clustered=True,
                                             lr=args.lr))
        start = 0
        if args.resume and args.ckpt_dir and \
                os.path.exists(os.path.join(args.ckpt_dir, "p.npz")):
            params = load_pytree(os.path.join(args.ckpt_dir, "p.npz"), params)
            mom = load_pytree(os.path.join(args.ckpt_dir, "m.npz"), mom)
            start = int(np.load(os.path.join(args.ckpt_dir, "step.npy")))
            print(f"resumed at step {start}")

        # Skip-One state per cluster (datacenter form: one "client" per
        # batch row; jittable mask builder)
        kappa = jnp.zeros((K, args.batch), jnp.int32)
        tau = jnp.zeros((K, args.batch), jnp.int32)
        phi = jnp.zeros((K, args.batch), jnp.float32)
        sp = skipone.SkipOneParams()
        n_k = jnp.ones((K,), jnp.float32)

        t0 = time.time()
        for it in range(start, args.steps):
            tok = jnp.asarray(rng.integers(
                0, cfg.vocab_size, (K, args.batch, args.seq + 1)),
                jnp.int32)
            # observed per-client step times (EMA stand-in: random jitter)
            tt = jnp.asarray(rng.lognormal(0, 0.3, (K, args.batch)),
                             jnp.float32)
            ee = jnp.ones((K, args.batch), jnp.float32)
            weights, (kappa, tau, phi) = skipone.select_jax(
                tt, ee, jnp.zeros_like(tt), kappa, tau, phi, sp)
            reach = np.ones((K, K), bool)
            M = crossagg.mixing_matrix(
                crossagg.sample_groups(reach, args.k_nbr, rng),
                np.ones(K))
            batch = {"tokens": tok[:, :, :-1], "labels": tok[:, :, 1:],
                     "weights": weights}
            params, mom, losses = step(params, mom, batch,
                                       jnp.asarray(M, jnp.float32))
            if it % 10 == 0 or it == args.steps - 1:
                print(f"step {it:4d} losses="
                      f"{[f'{float(l):.3f}' for l in losses]} "
                      f"({time.time() - t0:.0f}s)")
            if args.ckpt_dir and (it + 1) % args.ckpt_every == 0:
                os.makedirs(args.ckpt_dir, exist_ok=True)
                save_pytree(params, os.path.join(args.ckpt_dir, "p.npz"))
                save_pytree(mom, os.path.join(args.ckpt_dir, "m.npz"))
                np.save(os.path.join(args.ckpt_dir, "step.npy"), it + 1)

        final = S.consolidate_step(params, n_k)
        print(f"consolidated: "
              f"{sum(l.size for l in jax.tree.leaves(final))/1e6:.1f}M params")


if __name__ == "__main__":
    main()
