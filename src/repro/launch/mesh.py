"""Production meshes (see MULTI-POD DRY-RUN spec).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing
jax; smoke tests and benches see the 1 real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Tiny mesh over however many devices exist (tests on 1-8 CPU devs)."""
    n = len(jax.devices())
    if multi_pod:
        if n >= 4:
            return jax.make_mesh((2, n // 2, 1), ("pod", "data", "model"))
        return jax.make_mesh((1, n, 1), ("pod", "data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
