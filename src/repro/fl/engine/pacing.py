"""PacingPolicy implementations (DESIGN.md §8).

How per-cluster completion times fold into a round: who is charged idle
time, which fresh cluster models enter the mix, and how far the wall clock
advances. The engine calls exactly four hooks per round —

    begin_round -> account_cluster (per cluster, in-loop) -> merge -> advance

— so the barrier/wait accounting of every pacing scheme stays in one
place and every scenario shares the engine's select/train/upload/mix
skeleton (a pacing scheme is a policy, not a loop).

* ``SyncPacing``     — today's behavior, bit-for-bit: the round closes
  when the slowest cluster's slowest participant finishes; each cluster's
  members idle at their own cluster barrier.
* ``SemiSyncPacing`` — deadline rounds: the round closes at a deadline
  (a quantile of realized cluster barriers, or a fixed ``deadline_s``);
  stragglers' late updates are stashed and folded into the NEXT round's
  merge with weight ``beta`` (deadline-based semi-synchronous FL à la
  Razmi et al.'s visibility-barrier dodging).
* ``AsyncPacing``    — staleness-weighted fully-async merge (FedAsync):
  cluster updates are applied as convex combinations w_k <- (1-a)w_k +
  a*fresh with a = alpha0/(1+rank)^decay, rank = arrival order of the
  cluster this round; the wall clock advances by the MEAN cluster cycle
  (steady-state pipelined throughput), not the max.

Accounting invariants shared by all three: train energy is charged in
``account_cluster`` (same order as the sync engine), skipped members are
charged the full effective barrier, and nobody is charged waiting for time
they spent training.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.engine.base import EngineContext, RoundSelection
from repro.fl.robust import apply_robustness


def _bcast(vec, leaf):
    """(K,) -> (K, 1, ..., 1) broadcastable against a (K, ...) leaf."""
    return jnp.asarray(vec).reshape((-1,) + (1,) * (leaf.ndim - 1))


def weights_from_staleness(alpha0: float, decay: float, staleness,
                           tau: float = 1.0) -> np.ndarray:
    """alpha0 / (1 + s/tau)^decay — the FedAsync polynomial discount on
    a continuous staleness measure s. ``AsyncPacing`` feeds arrival RANK
    (tau=1, so s/tau is exact and the rank path stays bit-identical);
    the event-driven async pacing (repro.sim.driver) feeds sim-SECONDS
    with tau = the mean cluster cycle, making the discount scale-free in
    wall time."""
    s = np.asarray(staleness, np.float64)
    return alpha0 / (1.0 + s / tau) ** decay


def _charge_train(ctx: EngineContext, sel: RoundSelection, kc,
                  charge_wait: bool = True) -> float:
    """The uniform sync rule (engine docstring): charge participants'
    train energy (codec arith-scaled) and member idle at the cluster
    barrier; return the cluster barrier. ``charge_wait=False`` books the
    energy only — for policies (semi-sync) that can price idle only once
    the round-wide deadline is known."""
    mask, tt_r = sel.mask, sel.tt_r
    barrier = float(tt_r[mask].max()) if mask.any() else 0.0
    # energy/idle go through locals so observer and ledger see the SAME
    # floats (bit-exact reconciliation, DESIGN.md §10)
    e_tr = (float(ctx.et_full[sel.ids][mask].sum())
            * ctx.transport.arith_scale_for(kc))
    ctx.ledger.add_train(e_tr, barrier)
    if ctx.obs is not None:
        ctx.obs.train(kc, e_tr, barrier)
    if charge_wait:
        idle = float((barrier - tt_r[mask]).sum()
                     + barrier * (~mask).sum()
                     if mask.any() else 0.0)
        ctx.ledger.add_wait(idle)
        if ctx.obs is not None:
            ctx.obs.wait(idle, "barrier", kc)
    return barrier


class SyncPacing:
    """Synchronous barrier — the engine's historical behavior, preserved
    bit-for-bit (golden parity pins run through this policy)."""

    def begin_round(self, ctx: EngineContext, round_idx: int) -> None:
        pass

    def account_cluster(self, ctx: EngineContext, sel: RoundSelection,
                        kc: int) -> float:
        return _charge_train(ctx, sel, kc)

    def merge(self, ctx: EngineContext, model, state, new_models: list,
              sels: list, round_idx: int):
        return apply_robustness(ctx, model, state,
                                model.stack(new_models), sels)

    def merge_stacked(self, ctx: EngineContext, model, state, new_stacked,
                      sels: list, round_idx: int):
        return apply_robustness(ctx, model, state, new_stacked, sels)

    def advance(self, barriers: list) -> float:
        return max(barriers, default=0.0)

    def state_dict(self):
        return None

    def load_state_dict(self, state) -> None:
        pass


class SemiSyncPacing:
    """Deadline rounds with straggler folding.

    Deadline = ``deadline_s`` when given, else the ``quantile`` of this
    round's realized cluster barriers — capped at the slowest barrier
    either way (the round closes as soon as everyone is done; idle time
    is never booked past the wall-clock end of the round). Clusters
    finishing by the deadline merge now; a straggler's fresh model is
    stashed and convex-combined (weight ``beta``) into its cluster model
    at the NEXT round's merge, so late work is never dropped — it is just
    stale by one round. Members idle to the deadline only (a straggler's
    own overshoot is training, not waiting); skipped members idle the
    full deadline.

    The straggler stash is exported through ``state_dict()`` into
    ``SessionState.pacing_state`` at every round boundary (ckpt/store.py
    serializes it next to the cluster models), so a semi-sync disk resume
    is exact even with a deferred update pending — pinned by the
    resume-equals-uninterrupted test in tests/test_scenarios.py.
    """

    def __init__(self, quantile: float = 0.75, beta: float = 0.5,
                 deadline_s: Optional[float] = None):
        if not 0.0 < quantile <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {quantile}")
        if not 0.0 <= beta <= 1.0:
            raise ValueError(f"beta must be in [0, 1], got {beta}")
        self.quantile, self.beta, self.deadline_s = quantile, beta, deadline_s
        self._barriers: list[float] = []
        self._deadline = 0.0
        self._pending: dict[int, object] = {}   # kc -> stashed late model

    def begin_round(self, ctx: EngineContext, round_idx: int) -> None:
        self._barriers = []
        if round_idx == 0:        # fresh session: drop any stale stash
            self._pending = {}

    def account_cluster(self, ctx: EngineContext, sel: RoundSelection,
                        kc: int) -> float:
        # energy now (same in-loop order as sync, via the one shared
        # rule); idle deferred to merge, where the deadline is known
        barrier = _charge_train(ctx, sel, kc, charge_wait=False)
        self._barriers.append(barrier)
        return barrier

    def _close_round(self, ctx: EngineContext, sels: list):
        """Fix this round's deadline and book member idle (shared by the
        list and stacked merge paths — identical floats, same order)."""
        barriers = np.asarray(self._barriers)
        if barriers.size == 0:
            D = 0.0
        else:
            D = (self.deadline_s if self.deadline_s is not None
                 else float(np.quantile(barriers, self.quantile)))
            D = min(D, float(barriers.max()))   # round closes when all done
        self._deadline = D
        # idle: everyone waits to the deadline at most; stragglers' own
        # overshoot is work, not waiting
        for kc, sel in enumerate(sels):
            tt, mask = sel.tt_r, sel.mask
            idle = float(np.maximum(0.0, D - tt[mask]).sum()
                         + D * (~mask).sum())
            ctx.ledger.add_wait(idle)
            if ctx.obs is not None:
                ctx.obs.wait(idle, "deadline", kc)
        return barriers, D

    def merge(self, ctx: EngineContext, model, state, new_models: list,
              sels: list, round_idx: int):
        new_models = apply_robustness(ctx, model, state, new_models, sels)
        barriers, D = self._close_round(ctx, sels)
        K = len(new_models)
        old = model.unstack(state.cluster_models, K)
        merged = []
        fresh_pending: dict[int, object] = {}
        for kc in range(K):
            if barriers[kc] <= D:
                w_k = new_models[kc]                   # on time: merge now
            else:
                w_k = old[kc]                          # late: defer update
                fresh_pending[kc] = new_models[kc]
                if ctx.obs is not None:
                    ctx.obs.straggler(kc, "stash")
            if kc in self._pending:     # fold last round's straggler in
                w_k = _combine(model.stack([w_k, self._pending[kc]]),
                               self.beta)
                if ctx.obs is not None:
                    ctx.obs.straggler(kc, "fold")
            merged.append(w_k)
        self._pending = fresh_pending
        return model.stack(merged)

    def merge_stacked(self, ctx: EngineContext, model, state, new_stacked,
                      sels: list, round_idx: int):
        """Same semantics as ``merge`` on (K, ...) leaves: on-time clusters
        take their fresh model via a per-cluster ``where``, stragglers keep
        the old row and stash the fresh one, last round's stash folds in
        with weight beta."""
        new_stacked = apply_robustness(ctx, model, state, new_stacked, sels)
        barriers, D = self._close_round(ctx, sels)
        K = len(sels)
        on_time = barriers <= D if barriers.size else np.zeros(K, bool)
        merged = jax.tree.map(
            lambda old, new: jnp.where(_bcast(on_time, old), new,
                                       old).astype(old.dtype),
            state.cluster_models, new_stacked)
        fresh_pending = {
            kc: jax.tree.map(lambda l, kc=kc: l[kc], new_stacked)
            for kc in range(K) if not on_time[kc]}
        if ctx.obs is not None:
            for kc in fresh_pending:
                ctx.obs.straggler(kc, "stash")
        for kc, w_late in self._pending.items():
            merged = jax.tree.map(
                lambda l, wl, kc=kc: l.at[kc].set(
                    ((1.0 - self.beta) * l[kc]
                     + self.beta * wl).astype(l.dtype)),
                merged, w_late)
            if ctx.obs is not None:
                ctx.obs.straggler(kc, "fold")
        self._pending = fresh_pending
        return merged

    def advance(self, barriers: list) -> float:
        return self._deadline      # already capped at the slowest barrier

    def state_dict(self):
        """The straggler stash (kc -> deferred fresh model); ``None`` when
        nothing is pending so checkpoints stay byte-identical for sessions
        that never defer."""
        return {"pending": dict(self._pending)} if self._pending else None

    def load_state_dict(self, state) -> None:
        pending = (state or {}).get("pending") or {}
        self._pending = {int(kc): w for kc, w in pending.items()}


def _combine(stacked_pair, beta: float):
    """(2, ...) stacked pytree -> (1-beta)*first + beta*second per leaf."""
    return jax.tree.map(
        lambda leaf: ((1.0 - beta) * leaf[0] + beta * leaf[1]
                      ).astype(leaf.dtype),
        stacked_pair)


class AsyncPacing:
    """FedAsync-style staleness-weighted merge, clustered.

    Cluster updates are ranked by completion time; the k-th arrival is
    merged as w_k <- (1-a)w_k + a*fresh with a = alpha0/(1+rank)^decay
    (polynomial staleness discount — later arrivals trained against a
    model that more merges have already moved past). No cross-cluster
    barrier exists, so the wall clock advances by the MEAN cluster cycle
    time — the steady-state round throughput of a pipelined session —
    instead of the max. Intra-cluster idle (members waiting for their own
    cluster's barrier) is charged exactly as in sync.
    """

    def __init__(self, alpha0: float = 0.6, decay: float = 0.5):
        if not 0.0 < alpha0 <= 1.0:
            raise ValueError(f"alpha0 must be in (0, 1], got {alpha0}")
        self.alpha0, self.decay = alpha0, decay
        self._barriers: list[float] = []

    def begin_round(self, ctx: EngineContext, round_idx: int) -> None:
        self._barriers = []

    def account_cluster(self, ctx: EngineContext, sel: RoundSelection,
                        kc: int) -> float:
        barrier = _charge_train(ctx, sel, kc)
        self._barriers.append(barrier)
        return barrier

    @staticmethod
    def _ranks(barriers: np.ndarray) -> np.ndarray:
        ranks = np.empty(len(barriers), int)
        ranks[np.argsort(barriers, kind="stable")] = np.arange(len(barriers))
        return ranks

    def staleness_weights(self, barriers: np.ndarray) -> np.ndarray:
        return weights_from_staleness(self.alpha0, self.decay,
                                      self._ranks(barriers))

    def _observe_merge(self, ctx: EngineContext,
                       alphas: np.ndarray) -> None:
        if ctx.obs is None:
            return
        for kc, rk in enumerate(self._ranks(np.asarray(self._barriers))):
            ctx.obs.async_merge(kc, int(rk), float(alphas[kc]))

    def merge(self, ctx: EngineContext, model, state, new_models: list,
              sels: list, round_idx: int):
        new_models = apply_robustness(ctx, model, state, new_models, sels)
        K = len(new_models)
        alphas = self.staleness_weights(np.asarray(self._barriers))
        self._observe_merge(ctx, alphas)
        old = model.unstack(state.cluster_models, K)
        merged = [_combine(model.stack([old[kc], new_models[kc]]),
                           float(alphas[kc]))
                  for kc in range(K)]
        return model.stack(merged)

    def merge_stacked(self, ctx: EngineContext, model, state, new_stacked,
                      sels: list, round_idx: int):
        new_stacked = apply_robustness(ctx, model, state, new_stacked, sels)
        alphas = self.staleness_weights(np.asarray(self._barriers)
                                        ).astype(np.float32)
        self._observe_merge(ctx, alphas)
        return jax.tree.map(
            lambda old, new: ((1.0 - _bcast(alphas, old)) * old
                              + _bcast(alphas, new) * new).astype(old.dtype),
            state.cluster_models, new_stacked)

    def advance(self, barriers: list) -> float:
        return float(np.mean(barriers)) if barriers else 0.0

    def state_dict(self):
        return None                  # barriers reset every begin_round

    def load_state_dict(self, state) -> None:
        pass
