"""Transport: the single entry point of communication energy/latency into
the ledger, with a payload-codec hook (DESIGN.md §7-8).

Every GS or LISL message any policy accounts goes through one of the
three methods below, so all six algorithms share the exact same Eq. 5-6 /
12-13 arithmetic and the same payload definition. Compression schemes
(FedOrbit's block-minifloat, future quantizers) are codecs — they scale
the payload bits and the arithmetic energy, never fork the accounting.

Codecs may be engine-global (one ``PayloadCodec``) or heterogeneous per
training cluster (a ``CodecMap``): ``Transport.for_cluster(kc)`` returns a
view bound to cluster ``kc``'s codec over the same ledger, so e.g. a
block-minifloat codec on CPU-heavy clusters and identity on GPU clusters
coexist in one session without forking any accounting path (DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.energy import (CPU, EnergyLedger, LinkParams, e_gs, e_lisl,
                               t_gs, t_lisl)


@dataclass(frozen=True)
class IdentityCodec:
    """Full-precision payload (every algorithm except FedOrbit)."""
    name: str = "identity"
    arith_scale: float = 1.0         # compute-energy multiplier

    def payload_bits(self, model_bits: float) -> float:
        return model_bits


@dataclass(frozen=True)
class BlockMinifloatCodec:
    """FedOrbit's reduced-precision arithmetic: ``bits``-of-32 payload and
    ``arith_scale``-scaled compute energy (paper §V-A)."""
    bits: int = 12
    arith_scale: float = 0.5
    name: str = "block-minifloat"

    def payload_bits(self, model_bits: float) -> float:
        return model_bits * self.bits / 32.0


class CodecMap:
    """Training-cluster index -> PayloadCodec, with a default for unmapped
    clusters (and for messages with no cluster context, e.g. GS bootstrap).

    ``bind(plan, env)`` is called by the engine once the cluster plan
    exists; the static map ignores it, rule-based subclasses (below) derive
    their per-cluster assignment from it.
    """

    def __init__(self, default=None, per_cluster: Optional[dict] = None):
        self.default = default if default is not None else IdentityCodec()
        self.per_cluster: dict = dict(per_cluster or {})

    @property
    def name(self) -> str:
        return f"codec-map({self.default.name})"

    def bind(self, plan, env) -> "CodecMap":
        return self

    def codec_for(self, kc: Optional[int]):
        if kc is None:
            return self.default
        return self.per_cluster.get(int(kc), self.default)


class HardwareAwareCodecMap(CodecMap):
    """Heterogeneous-codec rule: clusters whose CPU-member fraction is at
    least ``cpu_threshold`` get ``cpu_codec`` (default block-minifloat —
    cheap arithmetic where compute energy is switched-capacitance bound),
    the rest get ``gpu_codec`` (default identity). Resolved against the
    actual cluster plan at ``bind`` time.
    """

    def __init__(self, cpu_codec=None, gpu_codec=None,
                 cpu_threshold: float = 0.5):
        super().__init__(default=gpu_codec if gpu_codec is not None
                         else IdentityCodec())
        self.cpu_codec = (cpu_codec if cpu_codec is not None
                          else BlockMinifloatCodec())
        self.cpu_threshold = cpu_threshold

    @property
    def name(self) -> str:
        return f"hw-aware({self.cpu_codec.name}|{self.default.name})"

    def bind(self, plan, env) -> "CodecMap":
        hw = np.array([p.hw_type for p in env.profiles])
        self.per_cluster = {
            kc: self.cpu_codec for kc, c in enumerate(plan.clusters)
            if float((hw[c] == CPU).mean()) >= self.cpu_threshold}
        return self


class Transport:
    """Accounts model-payload messages into an EnergyLedger.

    ``gs``/``intra``/``inter`` add ``n`` messages of one codec-encoded
    model payload each over the given distance; ``wait`` adds latency-only
    idle time (no energy, paper §III-C).

    ``codec`` may be a single PayloadCodec (engine-global, the default) or
    a ``CodecMap``; cluster-scoped policies call ``for_cluster(kc)`` to get
    a view with that cluster's codec over the same ledger.

    ``obs`` (an ``EngineObserver``) sees every message with the EXACT
    energy/time floats the ledger was charged; ``cluster`` labels which
    training cluster this view accounts for (``None`` for engine-global /
    GS-bootstrap traffic). With ``obs`` set, ``for_cluster`` returns
    cluster-labelled views even for the default codec — with it unset the
    pre-obs view caching (and thus the accounting path) is untouched.

    ``faults`` (a ``repro.faults.FaultState``, DESIGN.md §13) makes the
    three message methods fault-aware: a message hitting an active link
    outage is retried with exponential backoff — every failed attempt
    charges the FULL message energy/time (the transmitter really burned
    it) plus a ``wait(cause="retry")`` backoff — until the outage ends
    or ``max_retries`` attempts are exhausted (degraded-mode drop); a
    pending payload corruption/loss costs one charged retransmission.
    All retry charges flow through the same ``add_*``/``obs`` pairs as
    normal traffic, so the observer's mirror ledger stays bit-exact
    under faults by construction. With ``faults`` None (or no applicable
    fault) the accounting path is byte-identical to the pre-fault code.
    """

    RELAY_FALLBACK_M = 3e6   # nominal relayed path when instantaneously cut

    def __init__(self, ledger: EnergyLedger, link_params: LinkParams,
                 model_bits: float, codec=None, obs=None,
                 cluster: Optional[int] = None, faults=None):
        self.ledger = ledger
        self.lp = link_params
        self.model_bits = model_bits
        self.obs = obs
        self.cluster = cluster
        self.faults = faults         # repro.faults.FaultState | None
        if codec is None:
            codec = IdentityCodec()
        self.codec_map = (codec if isinstance(codec, CodecMap)
                          else CodecMap(default=codec))
        self.codec = self.codec_map.default
        self._views: dict = {}       # codec id / (codec id, kc) -> view

    def bind_clusters(self, plan, env) -> None:
        """Resolve rule-based codec maps against the built cluster plan."""
        self.codec_map.bind(plan, env)

    def for_cluster(self, kc: Optional[int]) -> "Transport":
        """View with cluster ``kc``'s codec (same ledger). Returns ``self``
        when the cluster uses the default codec, so engine-global codecs
        keep the exact pre-map accounting path. With an observer attached
        the view additionally carries ``cluster=kc`` so comm events are
        attributed (same ledger, same floats — labels only)."""
        c = self.codec_map.codec_for(kc)
        if self.obs is None and self.faults is None:
            if c is self.codec:
                return self
            view = self._views.get(id(c))
            if view is None:
                view = Transport(self.ledger, self.lp, self.model_bits, c)
                self._views[id(c)] = view
            return view
        # with an observer or fault state attached, views carry the
        # cluster label so comm attribution / outage scoping both work
        k = (id(c), None if kc is None else int(kc))
        view = self._views.get(k)
        if view is None:
            view = Transport(self.ledger, self.lp, self.model_bits, c,
                             obs=self.obs,
                             cluster=None if kc is None else int(kc),
                             faults=self.faults)
            self._views[k] = view
        return view

    def arith_scale_for(self, kc: Optional[int]) -> float:
        return self.codec_map.codec_for(kc).arith_scale

    @property
    def payload_bits(self) -> float:
        return self.codec.payload_bits(self.model_bits)

    @property
    def arith_scale(self) -> float:
        return self.codec.arith_scale

    # -- fault gate (repro.faults, DESIGN.md §13) ----------------------------
    def _deliver(self, link: str, add, n: int, d: float, e: float,
                 t: float) -> bool:
        """Charge any fault-recovery cost for one message batch; return
        True when the batch ultimately goes through (the caller then
        accounts the final successful copy exactly as it always did) and
        False on a degraded-mode drop after capped retries."""
        fs, obs, kc = self.faults, self.obs, self.cluster
        now = float(self.ledger.wall_clock_s)
        reason = fs.take_payload_fault(kc)
        if reason is not None:
            # the corrupted/lost first copy still burned the link
            add(n, e, t)
            if obs is not None:
                self.obs.comm(link, kc, n, d, e, t)
                obs.recovery("retransmit", now, cluster=kc, reason=reason,
                             link=link)
        end = fs.outage_end("gs" if link == "gs" else "lisl", kc, now)
        if end <= now:
            return True
        for attempt in range(fs.max_retries):
            # failed attempt: the transmitter burned the full message
            # cost into the outage, then backs off exponentially
            add(n, e, t)
            backoff = fs.backoff0_s * (2.0 ** attempt)
            self.ledger.add_wait(backoff)
            if obs is not None:
                self.obs.comm(link, kc, n, d, e, t)
                obs.wait(backoff, "retry", kc)
                obs.recovery("retry", now, cluster=kc, link=link,
                             attempt=attempt)
            now += backoff
            if now >= end:
                return True
        fs.dropped += 1
        if obs is not None:
            obs.recovery("drop", now, cluster=kc, link=link,
                         attempts=fs.max_retries)
        return False

    # -- message accounting --------------------------------------------------
    # e/t go through locals so observer and ledger see the SAME floats
    def gs(self, n: int, distance_m: float) -> None:
        d, lp = self.payload_bits, self.lp
        e = n * e_gs(d, lp.gs_rate, distance_m, lp)
        t = n * t_gs(d, lp.gs_rate, distance_m, lp)
        if self.faults is not None and \
                not self._deliver("gs", self.ledger.add_gs, n, d, e, t):
            return
        self.ledger.add_gs(n, e, t)
        if self.obs is not None:
            self.obs.comm("gs", self.cluster, n, d, e, t)

    def intra(self, n: int, distance_m: float) -> None:
        d, lp = self.payload_bits, self.lp
        e = n * e_lisl(d, lp.lisl_rate, distance_m, lp)
        t = n * t_lisl(d, lp.lisl_rate, distance_m, lp)
        if self.faults is not None and \
                not self._deliver("intra", self.ledger.add_intra, n, d, e, t):
            return
        self.ledger.add_intra(n, e, t)
        if self.obs is not None:
            self.obs.comm("intra", self.cluster, n, d, e, t)

    def inter(self, n: int, distance_m: float) -> None:
        d, lp = self.payload_bits, self.lp
        e = n * e_lisl(d, lp.lisl_rate, distance_m, lp)
        t = n * t_lisl(d, lp.lisl_rate, distance_m, lp)
        if self.faults is not None and \
                not self._deliver("inter", self.ledger.add_inter, n, d, e, t):
            return
        self.ledger.add_inter(n, e, t)
        if self.obs is not None:
            self.obs.comm("inter", self.cluster, n, d, e, t)

    def wait(self, seconds: float, cause: str = "contact") -> None:
        s = float(seconds)
        self.ledger.add_wait(s)
        if self.obs is not None:
            self.obs.wait(s, cause, self.cluster)
