"""Transport: the single entry point of communication energy/latency into
the ledger, with a payload-codec hook (DESIGN.md §7).

Every GS or LISL message any policy accounts goes through one of the
three methods below, so all six algorithms share the exact same Eq. 5-6 /
12-13 arithmetic and the same payload definition. Compression schemes
(FedOrbit's block-minifloat, future quantizers) are codecs — they scale
the payload bits and the arithmetic energy, never fork the accounting.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.energy import (EnergyLedger, LinkParams, e_gs, e_lisl, t_gs,
                               t_lisl)


@dataclass(frozen=True)
class IdentityCodec:
    """Full-precision payload (every algorithm except FedOrbit)."""
    name: str = "identity"
    arith_scale: float = 1.0         # compute-energy multiplier

    def payload_bits(self, model_bits: float) -> float:
        return model_bits


@dataclass(frozen=True)
class BlockMinifloatCodec:
    """FedOrbit's reduced-precision arithmetic: ``bits``-of-32 payload and
    ``arith_scale``-scaled compute energy (paper §V-A)."""
    bits: int = 12
    arith_scale: float = 0.5
    name: str = "block-minifloat"

    def payload_bits(self, model_bits: float) -> float:
        return model_bits * self.bits / 32.0


class Transport:
    """Accounts model-payload messages into an EnergyLedger.

    ``gs``/``intra``/``inter`` add ``n`` messages of one codec-encoded
    model payload each over the given distance; ``wait`` adds latency-only
    idle time (no energy, paper §III-C).
    """

    RELAY_FALLBACK_M = 3e6   # nominal relayed path when instantaneously cut

    def __init__(self, ledger: EnergyLedger, link_params: LinkParams,
                 model_bits: float, codec=None):
        self.ledger = ledger
        self.lp = link_params
        self.model_bits = model_bits
        self.codec = codec if codec is not None else IdentityCodec()

    @property
    def payload_bits(self) -> float:
        return self.codec.payload_bits(self.model_bits)

    @property
    def arith_scale(self) -> float:
        return self.codec.arith_scale

    # -- message accounting --------------------------------------------------
    def gs(self, n: int, distance_m: float) -> None:
        d, lp = self.payload_bits, self.lp
        self.ledger.add_gs(n, n * e_gs(d, lp.gs_rate, distance_m, lp),
                           n * t_gs(d, lp.gs_rate, distance_m, lp))

    def intra(self, n: int, distance_m: float) -> None:
        d, lp = self.payload_bits, self.lp
        self.ledger.add_intra(n, n * e_lisl(d, lp.lisl_rate, distance_m, lp),
                              n * t_lisl(d, lp.lisl_rate, distance_m, lp))

    def inter(self, n: int, distance_m: float) -> None:
        d, lp = self.payload_bits, self.lp
        self.ledger.add_inter(n, n * e_lisl(d, lp.lisl_rate, distance_m, lp),
                              n * t_lisl(d, lp.lisl_rate, distance_m, lp))

    def wait(self, seconds: float) -> None:
        self.ledger.add_wait(float(seconds))
