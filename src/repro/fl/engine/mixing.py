"""MixingPolicy implementations (DESIGN.md §7).

How models move between training clusters each round, plus the session
endpoints (bootstrap distribution / final collection). All communication
is accounted through ``ctx.transport`` — no policy touches the ledger's
energy arithmetic directly.

* ``CrossAggMixing``     — CroSatFL: intra-cluster upload to masters (with
  state-free master migration), random-k cross-aggregation among reachable
  masters, GS contact only at bootstrap + final collection.
* ``GSStarMixing``       — FedSyn: every participant syncs up+down with the
  GS every round; the round closes when the last client has synced.
* ``SinkChainMixing``    — FedLEO: updates propagate along per-plane chains
  to a sink; sinks are the only GS contacts.
* ``HeadChainMixing``    — FELLO: members upload to neighborhood heads,
  heads chain to one elected head, the single GS contact per round.
* ``RelayedGSStarMixing``— FedSCS / FedOrbit: participants relay over two
  LISL hops to a GS-visible satellite, then sync with the GS.
* ``GossipMixing``       — gossip-only sessions with NO GS contact at all
  (DESIGN.md §8): bootstrap by LISL flooding from a seed satellite,
  random-k gossip between rounds, finalize via consensus rounds whose
  count comes from the ``consensus_contraction`` mixing bound.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import crossagg
from repro.fl.engine.base import (ClusterPlan, EngineContext, RoundSelection,
                                  SessionState)

_CHAIN_FALLBACK_M = 3e6       # chain hop when the direct link is cut
_RELAY_HOP_M = 1.2e6          # FedSCS nominal LISL relay hop


def _finite_or(dist: float, fallback: float) -> float:
    return dist if np.isfinite(dist) else fallback


def _components(adj: np.ndarray) -> list[list[int]]:
    """Connected components of a symmetric bool adjacency (DFS)."""
    K = adj.shape[0]
    seen = np.zeros(K, bool)
    comps = []
    for s in range(K):
        if seen[s]:
            continue
        stack, comp = [s], []
        seen[s] = True
        while stack:
            i = stack.pop()
            comp.append(i)
            for j in np.flatnonzero(adj[i]):
                if not seen[j]:
                    seen[j] = True
                    stack.append(j)
        comps.append(comp)
    return comps


class CrossAggMixing:
    """Paper §IV-C (Eq. 34-38) + §III-A master migration.

    ``backend`` picks the executor for the mixing contraction itself:
    ``"einsum"`` (the reference, core/crossagg.apply_mixing) or
    ``"pallas"`` (the fused kernels/cross_agg tile kernel — interpret mode
    off-TPU, float-tolerance parity pinned in tests).
    """

    def __init__(self, k_nbr: int = 2, backend: str = "einsum"):
        self.k_nbr = k_nbr
        self.backend = backend

    # -- helpers -------------------------------------------------------------
    def _dist(self, ctx, i: int, j: int, t: float) -> float:
        return _finite_or(ctx.env.lisl_distance(int(i), int(j), t),
                          ctx.transport.RELAY_FALLBACK_M)

    def _migrate(self, ctx, cluster_ids: np.ndarray, from_sat: int,
                 t_now: float) -> int:
        """Pick the member reachable from ``from_sat`` with max fan-out."""
        env = ctx.env
        best, best_fo = cluster_ids[0], -1
        for j in cluster_ids:
            if j == from_sat:
                continue
            if np.isfinite(env.lisl_distance(int(from_sat), int(j), t_now)):
                fo = env.fanout[j]
                if fo > best_fo:
                    best, best_fo = j, fo
        return int(best)

    # -- MixingPolicy --------------------------------------------------------
    def bootstrap(self, ctx: EngineContext, plan: ClusterPlan,
                  state: SessionState) -> None:
        """GS bootstrap: one downlink per cluster master, then each master
        relays w0 inside its cluster over LISLs."""
        env, tr = ctx.env, ctx.transport
        t_now = 0.0
        for mk in state.masters:
            wait, dist = env.gs_window_wait(int(mk), t_now)
            tr.wait(wait)
            tr.gs(1, dist)
        for kc, (c, mk) in enumerate(zip(plan.clusters, state.masters)):
            tr_k = tr.for_cluster(kc)
            for i in c:
                if i == mk:
                    continue
                tr_k.intra(1, self._dist(ctx, int(mk), int(i), t_now))

    def upload(self, ctx: EngineContext, plan: ClusterPlan,
               state: SessionState, kc: int, participants: np.ndarray,
               t_now: float) -> None:
        env, tr = ctx.env, ctx.transport.for_cluster(kc)
        mk = state.masters[kc]
        for i in participants:
            if i == mk:
                continue
            dist = env.lisl_distance(int(i), int(mk), t_now)
            if not np.isfinite(dist):
                # master migration: re-designate a reachable member
                old_mk = int(mk)
                mk = self._migrate(ctx, plan.clusters[kc], i, t_now)
                state.masters[kc] = mk
                dist = self._dist(ctx, int(i), int(mk), t_now)
                if ctx.obs is not None:
                    ctx.obs.note("master_migration", cluster=int(kc),
                                 old_master=old_mk, new_master=int(mk))
            tr.intra(1, dist)

    def mix(self, ctx: EngineContext, plan: ClusterPlan, state: SessionState,
            stacked, N_k: np.ndarray, sels: list[RoundSelection],
            round_idx: int, t_round: float, t_now: float):
        env, tr = ctx.env, ctx.transport
        reach = env.master_reach(state.masters, t_round)
        groups = crossagg.sample_groups(reach, self.k_nbr, ctx.rng)
        M = crossagg.mixing_matrix(groups, N_k)
        stacked = crossagg.apply_mixing(M, stacked, backend=self.backend)
        for kc, g in enumerate(groups):
            for j in g:
                if j == kc:
                    continue
                # payload encoded by the SENDER's cluster codec
                tr.for_cluster(int(j)).inter(
                    1, self._dist(ctx, int(state.masters[j]),
                                  int(state.masters[kc]), t_round))
        return stacked, 0.0

    def finalize(self, ctx: EngineContext, plan: ClusterPlan,
                 state: SessionState, N_k: np.ndarray, wall: float):
        """Consolidation (Eq. 38) + single GS downlink per master."""
        env, tr = ctx.env, ctx.transport
        w_final = crossagg.consolidate(state.cluster_models, N_k)
        for mk in state.masters:
            wait, dist = env.gs_window_wait(int(mk), wall)
            tr.wait(wait)
            tr.gs(1, dist)
        return w_final


class GossipMixing(CrossAggMixing):
    """Fully on-orbit sessions: NO ground-station contact, ever.

    Bootstrap: the initial model lives on a seed satellite (the highest
    fan-out master — e.g. pre-loaded at launch or injected out-of-band)
    and floods over LISLs: a BFS tree over the instantaneous master
    reachability graph carries w0 master-to-master, then each master
    relays to its cluster members. Rounds gossip exactly like CroSatFL's
    random-k cross-aggregation. Finalize: instead of a GS collection, the
    masters run Metropolis-weighted consensus rounds over their full
    neighborhoods; the number of rounds comes from the
    ``consensus_contraction`` bound sigma_2 (disagreement contracts by
    sigma_2 per round, so ceil(log eps / log sigma_2) rounds reach
    ``consensus_eps``), reported in ``plan.meta['gossip_consensus']``.
    """

    def __init__(self, k_nbr: int = 2, consensus_eps: float = 1e-2,
                 max_consensus_rounds: int = 8, backend: str = "einsum"):
        super().__init__(k_nbr=k_nbr, backend=backend)
        self.consensus_eps = consensus_eps
        self.max_consensus_rounds = max_consensus_rounds
        self.last_consensus: dict = {}   # report of the final consensus pass

    def bootstrap(self, ctx: EngineContext, plan: ClusterPlan,
                  state: SessionState) -> None:
        env, tr = ctx.env, ctx.transport
        masters = state.masters
        if len(masters) == 0:
            return
        t_now = 0.0
        seed = int(np.argmax(env.fanout[masters]))
        reach = env.master_reach(masters, t_now)
        # BFS flood tree over the master graph; islands get one relayed
        # (fallback-distance) hop from the seed
        visited, frontier = {seed}, [seed]
        while frontier:
            nxt = []
            for p in frontier:
                for q in range(len(masters)):
                    if q not in visited and reach[p, q]:
                        visited.add(q)
                        nxt.append(q)
                        # priced by the SENDER's (relaying master's) codec,
                        # like every other inter-cluster message
                        tr.for_cluster(p).inter(
                            1, self._dist(ctx, int(masters[p]),
                                          int(masters[q]), t_now))
            frontier = nxt
        for q in range(len(masters)):
            if q not in visited:
                tr.for_cluster(seed).inter(1, tr.RELAY_FALLBACK_M)
        for kc, (c, mk) in enumerate(zip(plan.clusters, masters)):
            tr_k = tr.for_cluster(kc)
            for i in c:
                if i == mk:
                    continue
                tr_k.intra(1, self._dist(ctx, int(mk), int(i), t_now))

    def finalize(self, ctx: EngineContext, plan: ClusterPlan,
                 state: SessionState, N_k: np.ndarray, wall: float):
        env, tr = ctx.env, ctx.transport
        K = len(state.masters)
        if K == 0:
            return crossagg.consolidate(state.cluster_models, N_k)
        adj = np.asarray(env.master_reach(state.masters, wall), bool)
        adj = adj | adj.T
        np.fill_diagonal(adj, False)
        # bridge islands (masters beyond max_hops) through a relayed
        # fallback hop to the hub master — same fallback the gossip mix
        # and bootstrap use; _dist prices those edges at RELAY_FALLBACK_M
        hub = int(np.argmax(env.fanout[state.masters]))
        for comp in _components(adj):
            if hub not in comp:
                adj[hub, comp[0]] = adj[comp[0], hub] = True
        M = crossagg.metropolis_matrix(adj)
        # Metropolis weights are doubly stochastic, so the contraction
        # bound is taken with uniform pi (< 1 iff the graph is connected)
        sigma2 = crossagg.consensus_contraction(M, np.ones(K))
        if sigma2 <= 0.0:
            n_rounds = 1                           # one round reaches exact
        elif sigma2 < 1.0:                         # consensus (e.g. K == 2)
            n_rounds = math.ceil(math.log(self.consensus_eps)
                                 / math.log(sigma2))
        else:
            n_rounds = self.max_consensus_rounds   # K == 1 or degenerate
        n_rounds = max(1, min(n_rounds, self.max_consensus_rounds))
        edges = [(i, j) for i in range(K)
                 for j in np.flatnonzero(adj[i]) if i < j]
        for _ in range(n_rounds):
            state.cluster_models = crossagg.apply_mixing(
                M, state.cluster_models, backend=self.backend)
            for i, j in edges:      # pairwise exchange along every edge
                d = self._dist(ctx, int(state.masters[i]),
                               int(state.masters[j]), wall)
                tr.for_cluster(int(i)).inter(1, d)
                tr.for_cluster(int(j)).inter(1, d)
        self.last_consensus = {
            "sigma2": float(sigma2), "rounds": int(n_rounds),
            "eps": self.consensus_eps}
        plan.meta["gossip_consensus"] = self.last_consensus
        if ctx.obs is not None:
            ctx.obs.note("gossip_consensus", **self.last_consensus)
        return crossagg.consolidate(state.cluster_models, N_k)


class _GSCentricMixing:
    """Shared no-op endpoints: GS-centric baselines fold model download
    into their per-round sync, so bootstrap/upload/finalize add nothing."""

    def bootstrap(self, ctx, plan, state) -> None:
        pass

    def upload(self, ctx, plan, state, kc, participants, t_now) -> None:
        pass

    def finalize(self, ctx, plan, state, N_k, wall):
        return crossagg.consolidate(state.cluster_models, N_k)

    def _barrier_waits(self, tr, waits: list[float]) -> float:
        """Synchronous round: ends when the LAST client has synced;
        everyone else idles (latency-only waiting). A zero-participant
        round (selection produced nobody) has no sync barrier."""
        if not waits:
            return 0.0
        wmax = max(waits)
        tr.wait(float(np.sum(wmax - np.asarray(waits))), "sync")
        return wmax


class GSStarMixing(_GSCentricMixing):
    """FedSyn: per participant, one upload + one download per round."""

    def mix(self, ctx, plan, state, stacked, N_k, sels, round_idx,
            t_round, t_now):
        env, tr = ctx.env, ctx.transport
        waits = []
        for i in (sels[0].participants if sels else ()):
            wait, dist = env.gs_window_wait(int(i), t_now)
            waits.append(wait)
            tr.gs(2, dist)
        return stacked, self._barrier_waits(tr, waits)


class SinkChainMixing(_GSCentricMixing):
    """FedLEO: chain propagation to per-plane sinks, sinks talk to GS."""

    def mix(self, ctx, plan, state, stacked, N_k, sels, round_idx,
            t_round, t_now):
        env, tr = ctx.env, ctx.transport
        waits = []
        for g in plan.comm_groups:
            sink = int(g[np.argmax(env.fanout[g])])
            # chain to sink and back: 2 LISL msgs per non-sink member
            for i in g:
                if int(i) == sink:
                    continue
                tr.intra(2, _finite_or(env.lisl_distance(int(i), sink, t_now),
                                       _CHAIN_FALLBACK_M))
            wait, gdist = env.gs_window_wait(sink, t_now)
            waits.append(wait)
            tr.gs(2, gdist)
        return stacked, self._barrier_waits(tr, waits)


class HeadChainMixing(_GSCentricMixing):
    """FELLO: members -> heads -> elected head -> single GS contact."""

    def mix(self, ctx, plan, state, stacked, N_k, sels, round_idx,
            t_round, t_now):
        env, tr = ctx.env, ctx.transport
        heads = plan.heads
        for c, h in zip(plan.comm_groups, heads):
            for i in c:
                if int(i) == int(h):
                    continue
                tr.intra(2, _finite_or(
                    env.lisl_distance(int(i), int(h), t_now),
                    _CHAIN_FALLBACK_M))
        elect = int(heads[0])
        for h in heads[1:]:
            tr.intra(2, _finite_or(env.lisl_distance(int(h), elect, t_now),
                                   _CHAIN_FALLBACK_M))
        wait, gdist = env.gs_window_wait(elect, t_now)
        tr.gs(2, gdist)
        return stacked, wait


class RelayedGSStarMixing(_GSCentricMixing):
    """FedSCS / FedOrbit: 2 LISL relay hops (up + down) to a GS-visible
    satellite, then one GS up + down per participant."""

    def mix(self, ctx, plan, state, stacked, N_k, sels, round_idx,
            t_round, t_now):
        env, tr = ctx.env, ctx.transport
        waits = []
        for i in (sels[0].participants if sels else ()):
            tr.intra(4, _RELAY_HOP_M)
            wait, gdist = env.gs_window_wait(int(i), t_now)
            waits.append(wait)
            tr.gs(2, gdist)
        return stacked, self._barrier_waits(tr, waits)
