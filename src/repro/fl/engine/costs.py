"""Feed measured datacenter costs into the orbital energy model.

The FL energy model (core/energy.py, Eq. 2-4) is parameterized by
``c_flop`` — FLOPs per training sample — which the seed hardcoded at 5e7.
This module derives it from the while-aware compiled-HLO cost model
(launch/hlo_cost.py) instead, so Table-II energy rows reflect what the
dry-run matrix actually measured for a given architecture.

``EngineConfig.c_flop`` (and the legacy ``SessionConfig``/
``BaselineConfig`` shims) accept a spec string

    "measured:<arch>[/<shape>]"        e.g. "measured:gemma3-1b/train_4k"

resolved by ``resolve_c_flop`` at engine construction:

1. If a dry-run JSONL row for the cell exists (results/dryrun*.jsonl,
   written by ``python -m repro.launch.dryrun --json``), use its
   HLO-measured FLOPs divided by the cell's global batch.
2. Otherwise compile the arch's ``reduced()`` config on the local devices,
   run ``analyze_hlo`` over the compiled module, and scale per-token FLOPs
   by the full/reduced active-parameter ratio (6·N·D both ways, so the
   ratio is exact for the matmul-dominated term; the attention O(S^2)
   share is approximated).

Estimates are cached in results/measured_cflop.json.
"""
from __future__ import annotations

import dataclasses
import json
import os

_CACHE = None                 # override (tests); default: <results>/measured_cflop.json
_DRYRUN_GLOBS = ("dryrun_opt.jsonl", "dryrun.jsonl")
_PROBE_BATCH = 4
_PROBE_SEQ = 128


def _results_dir() -> str:
    """Where dry-run rows are looked up and the estimate cache lives:
    next to the explicit cache override when set, else
    $CROSATFL_RESULTS_DIR, else ./results (matching benchmarks/ output)."""
    if _CACHE:
        return os.path.dirname(os.path.abspath(_CACHE))
    return os.environ.get("CROSATFL_RESULTS_DIR",
                          os.path.join(os.getcwd(), "results"))


def _cache_path() -> str:
    return _CACHE or os.path.join(_results_dir(), "measured_cflop.json")


def _from_dryrun_rows(arch: str, shape: str) -> float | None:
    """FLOPs/sample from a saved dry-run row (HLO-measured, full scale)."""
    from repro.configs.base import SHAPES
    for name in _DRYRUN_GLOBS:
        path = os.path.join(_results_dir(), name)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                try:
                    row = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if (row.get("arch") == arch and row.get("shape") == shape
                        and row.get("status") == "ok"
                        and row.get("flops", 0) > 0):
                    return float(row["flops"]) / SHAPES[shape].global_batch
    return None


def _probe_compile(arch: str, shape: str) -> float:
    """Compile the reduced config locally, measure HLO FLOPs, scale up."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import SHAPES, ShapeConfig, get_config, input_specs
    from repro.launch import steps as S
    from repro.launch.hlo_cost import analyze_hlo
    from repro.launch.mesh import make_test_mesh
    from repro.models import api

    full = get_config(arch)
    cfg = full.reduced()
    probe = ShapeConfig("cflop_probe", _PROBE_SEQ, _PROBE_BATCH, "train")
    specs = input_specs(cfg, probe)
    specs["weights"] = jax.ShapeDtypeStruct((probe.global_batch,),
                                            jnp.float32)
    params = api.param_specs(cfg)
    mesh = make_test_mesh()
    with mesh:
        step = S.build_fl_train_step(cfg, mesh, clustered=False, tp=False)
        compiled = jax.jit(step).lower(params, params, specs).compile()
    flops = analyze_hlo(compiled.as_text()).flops * len(jax.devices())
    per_token = flops / (probe.global_batch * probe.seq_len)
    ratio = (api.count_params(full, active_only=True)
             / api.count_params(cfg, active_only=True))
    return per_token * ratio * SHAPES[shape].seq_len


def measured_c_flop(arch: str = "gemma3-1b", shape: str = "train_4k",
                    refresh: bool = False) -> float:
    """FLOPs per training sample for one (arch, shape) cell."""
    cell = f"{arch}/{shape}"
    cache_path = _cache_path()
    cache = {}
    if os.path.exists(cache_path):
        try:
            with open(cache_path) as f:
                cache = json.load(f)
        except (json.JSONDecodeError, OSError):
            cache = {}
    if not refresh and cell in cache:
        entry = cache[cell]
        if entry.get("source") == "dryrun-jsonl":
            return float(entry["c_flop"])
        # a cached reduced-probe ESTIMATE is only a fallback: a dry-run
        # row saved since (launch/dryrun persists to results/ by default)
        # carries the real HLO-measured FLOPs for the cell and must win —
        # returning the stale probe forever was the ROADMAP's "gemma cell
        # falls back to the reduced-probe estimate" bug
        row = _from_dryrun_rows(arch, shape)
        if row is None:
            return float(entry["c_flop"])
        value, source = row, "dryrun-jsonl"
    else:
        value = _from_dryrun_rows(arch, shape)
        source = "dryrun-jsonl"
        if value is None:
            value = _probe_compile(arch, shape)
            source = "reduced-probe"
    cache[cell] = {"c_flop": value, "source": source}
    try:
        os.makedirs(_results_dir(), exist_ok=True)
        with open(cache_path, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
    except OSError:
        pass
    return float(value)


def resolve_c_flop(cfg):
    """Return ``cfg`` with a numeric ``c_flop`` (resolving "measured:..."
    specs); configs that already carry a number pass through unchanged."""
    spec = cfg.c_flop
    if isinstance(spec, (int, float)):
        return cfg
    if isinstance(spec, str) and spec.startswith("measured:"):
        cell = spec[len("measured:"):]
        arch, _, shape = cell.partition("/")
        value = measured_c_flop(arch, shape or "train_4k")
        return dataclasses.replace(cfg, c_flop=value)
    raise ValueError(f"unsupported c_flop spec: {spec!r}")
