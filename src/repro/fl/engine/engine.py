"""RoundEngine: the one orchestrator behind CroSatFL, all five
baselines, and the scenario zoo (DESIGN.md §7-8).

Owns the canonical edge-round skeleton —

    for each round:
        for each training cluster:
            select participants        (SelectionPolicy)
            account train/idle         (PacingPolicy.account_cluster)
            intra-upload               (MixingPolicy.upload)
        local-train all clusters       (Executor.train_clusters: the
                                        sequential cluster_round loop, ONE
                                        batched fleet call, or the fleet
                                        call pod-sharded across devices —
                                        cfg.executor, repro.fl.exec)
        fold fresh cluster models      (Executor.fold routes into
                                        PacingPolicy.merge / merge_stacked)
        mix cluster models             (MixingPolicy.mix)
        advance wall clock             (PacingPolicy.advance), evaluate

— plus session endpoints (bootstrap / finalize) and checkpoint-resume.
Local training touches neither the ledger nor either RNG stream, so the
sequential executor stays bit-for-bit against the pre-refactor golden
pins while training itself is free to batch or shard (DESIGN.md §9, §12).

Uniform accounting rule (paper §III-B/C), under the default SyncPacing,
per cluster per round:

    barrier   = max realized train time over participants
    energy   += sum of participant train energy x codec arith_scale
    waiting  += sum over members of (barrier - work_i)
                (participants idle for barrier - t_i; Skip-One'd members
                do no work and idle the full barrier)

Every algorithm gets exactly this rule — accounting drift between
implementations (the pre-refactor failure mode) is impossible by
construction. Semi-sync / async pacing policies replace the barrier with
a deadline / staleness-weighted merge but keep the same invariants
(pacing.py).

Checkpoint-resume is bit-reproducible: ``SessionState`` carries both the
JAX ``rng_key`` and the host numpy bit-generator state (``rng_state`` —
selection jitter, cross-agg group sampling and top-m noise all draw from
the host RNG), so a resumed session replays the uninterrupted ledger and
weights exactly.
"""
from __future__ import annotations

import os
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import crossagg
from repro.core.energy import GPU, EnergyLedger, e_train, t_train
from repro.fl.engine.base import (ClusterPlan, EngineConfig, EngineContext,
                                  RoundSelection, SessionState)
from repro.fl.engine.costs import resolve_c_flop
from repro.fl.engine.pacing import SyncPacing
from repro.fl.engine.transport import IdentityCodec, Transport
from repro.fl.exec import resolve_executor
from repro.obs.jaxprof import annotate


def _hw_penalty(hw: np.ndarray) -> np.ndarray:
    """H_i: rare hardware is expensive to skip (Eq. 33)."""
    frac_gpu = hw.mean()
    rare_gpu = 1.0 - frac_gpu
    return np.where(hw == GPU, rare_gpu, frac_gpu)


class RoundEngine:
    """One federated session = policies x engine over (env, model).

    ``env`` duck-type (constellation/sim.py provides it):
        n_clients, profiles, n_samples, link_params, fanout,
        lisl_distance(i, j, t), master_reach(masters, t),
        gs_window_wait(sat, t), constellation
    ``model`` duck-type (fl/client.py):
        init(key) -> params
        cluster_round(w, participant_ids, n_samples, epochs, key) -> w'
        stack(list_of_params) / unstack(stacked, K)
    """

    def __init__(self, cfg: EngineConfig, env, model, *, clustering,
                 selection, mixing, codec=None, pacing=None,
                 name: str = "engine", observer=None, faults=None):
        cfg = resolve_c_flop(cfg)
        self.cfg, self.env, self.model = cfg, env, model
        self.clustering, self.selection, self.mixing = \
            clustering, selection, mixing
        self.codec = codec if codec is not None else IdentityCodec()
        self.pacing = pacing if pacing is not None else SyncPacing()
        self.observer = observer     # EngineObserver | None (repro.obs)
        # fault injection (repro.faults, DESIGN.md §13): None, a
        # FaultSchedule, or a prebuilt FaultInjector. With None attached
        # every fault code path below is a pointer comparison — the
        # golden ledgers stay bit-for-bit
        if faults is not None:
            from repro.faults import as_injector
            faults = as_injector(faults)
        self.faults = faults
        # Byzantine-robust merge + quorum gate (repro.fl.robust,
        # DESIGN.md §14). The fedavg/None defaults make every pacing
        # merge a pass-through — golden bit-parity by construction
        from repro.fl.robust import resolve_aggregator, resolve_quorum
        self.robust = resolve_aggregator(getattr(cfg, "aggregator",
                                                 "fedavg"))
        self.quorum = resolve_quorum(getattr(cfg, "quorum", None))
        if self.faults is not None:
            # configurable retry policy: EngineConfig knobs override the
            # schedule's; None keeps them (golden ledgers bit-for-bit).
            # FaultState.reset() preserves these, and a resumed
            # snapshot's own values win on load (they recorded the run)
            if getattr(cfg, "retry_base_s", None) is not None:
                self.faults.state.backoff0_s = float(cfg.retry_base_s)
            if getattr(cfg, "retry_max_attempts", None) is not None:
                self.faults.state.max_retries = int(cfg.retry_max_attempts)
        self.name = name
        self.executor = resolve_executor(cfg, model)   # repro.fl.exec
        self.rng = np.random.default_rng(cfg.seed)
        self._plan_cache = None      # (policy_params, plan, post-build key)

        alpha = np.array([p.alpha for p in env.profiles])
        hw = np.array([p.hw_type for p in env.profiles])
        self._alpha, self._hw = alpha, hw

    def _make_ctx(self, ledger: EnergyLedger) -> EngineContext:
        cfg, env = self.cfg, self.env
        return EngineContext(
            cfg=cfg, env=env, model=self.model,
            transport=Transport(ledger, env.link_params, cfg.model_bits,
                                self.codec, obs=self.observer,
                                faults=None if self.faults is None
                                else self.faults.state),
            rng=self.rng, obs=self.observer,
            robust=self.robust, quorum=self.quorum,
            tt_full=t_train(env.n_samples, cfg.c_flop, self._alpha,
                            cfg.local_epochs),
            et_full=e_train(env.n_samples, cfg.c_flop, env.profiles,
                            cfg.local_epochs),
            hw_penalty=_hw_penalty(self._hw))

    # -- round body: local training ------------------------------------------
    def _train_round(self, state: SessionState, sels, subs, r: int):
        """Train every cluster's participants and fold the pacing merge.

        HOW the training runs is the executor's business (repro.fl.exec,
        DESIGN.md §12): sequential per-cluster ``cluster_round`` calls
        (the golden bit-parity reference), ONE nested-vmap fleet call, or
        the fleet call pod-sharded across devices. ``Executor.fold`` owns
        the ``merge`` / ``merge_stacked`` routing so pacing policies never
        branch on execution mode.
        """
        ex = self.executor
        with annotate(f"exec:{ex.name}"):
            result = ex.train_clusters(self._ctx, self.last_plan, state,
                                       sels, subs, r)
        if self.faults is not None:
            # silent corruption lands HERE — after training, before the
            # merge: the checksum saw a valid payload, the values are
            # poison (DESIGN.md §14). No-op without pending descriptors
            result = self.faults.corrupt_result(self._ctx, self.model,
                                                result, sels)
        return ex.fold(self._ctx, self.pacing, state, result, sels, r)

    # -- session -------------------------------------------------------------
    def run(self, rounds: Optional[int] = None,
            eval_fn: Optional[Callable] = None,
            state: Optional[SessionState] = None,
            ckpt_dir: Optional[str] = None,
            ckpt_every: int = 1,
            eval_every: int = 1,
            ):
        """``eval_every``: evaluate every N rounds (plus always the final
        round) — long benchmark sessions stop blocking on a host-synced
        eval each round; history rows keep their true round index."""
        cfg, env, model = self.cfg, self.env, self.model
        R = rounds if rounds is not None else cfg.rounds
        key = jax.random.PRNGKey(cfg.seed)

        ledger = state.ledger if state is not None else EnergyLedger()
        ctx = self._ctx = self._make_ctx(ledger)
        # the cluster plan is a pure function of (env, cfg.seed,
        # policy_params) — build() consumes only deterministic jax-key
        # splits — so repeat run() calls on one engine (benchmark warmup +
        # timed run, resume-in-place) reuse it instead of re-running the
        # StarMask rollout, which otherwise dominates short sessions
        pp = getattr(self.clustering, "policy_params", None)
        # identity comparison: policy_params may be a dict of arrays
        # (StarMask policy weights), where == would compare element-wise;
        # a distinct-but-equal object just rebuilds (correct, not cached)
        if self._plan_cache is not None and self._plan_cache[0] is pp:
            plan, key = self._plan_cache[1], self._plan_cache[2]
        else:
            plan, key = self.clustering.build(ctx, key)
            self._plan_cache = (pp, plan, key)
        ctx.transport.bind_clusters(plan, env)
        self.last_plan = plan
        K = plan.n_clusters
        N_k = np.array([env.n_samples[c].sum() for c in plan.clusters],
                       np.float64)
        self.executor.prepare(cfg, env, model, plan)

        obs = self.observer
        if obs is not None:
            obs.session_start(self.name, plan, cfg, ledger.wall_clock_s)
            obs.note("executor", impl=self.executor.name)

        if state is None:
            key, sub = jax.random.split(key)
            w0 = model.init(sub)
            # copy: master migration mutates state.masters in place, and
            # the cached plan must stay pristine for the next run()
            masters = (plan.masters.copy() if plan.masters is not None
                       else np.zeros(0, int))
            state = SessionState(
                round_idx=0, cluster_models=model.stack([w0] * K),
                skip_states=[self.selection.init_state(len(c))
                             for c in plan.clusters],
                masters=masters, rng_key=key, ledger=ledger)
            if obs is not None:
                obs.phase_start("bootstrap")
            self.mixing.bootstrap(ctx, plan, state)
            if obs is not None:
                obs.phase_end("bootstrap")
            state.rng_state = self.rng.bit_generator.state
        else:
            if state.rng_state is not None:
                # resume: restore the host RNG mid-stream, or selection
                # jitter / group sampling silently diverge from the
                # uninterrupted run
                self.rng.bit_generator.state = state.rng_state
            if hasattr(self.pacing, "load_state_dict"):
                # unconditionally: a None snapshot must CLEAR any stash a
                # previous run() left on this (reused) policy instance
                self.pacing.load_state_dict(getattr(state, "pacing_state",
                                                    None))
            if self.faults is not None:
                # same discipline: restore the fault kernel (pending
                # future events included) + live view, or clear a reused
                # injector — a mid-campaign resume replays the
                # uninterrupted fault timeline bit-for-bit
                self.faults.load_state_dict(getattr(state, "faults_state",
                                                    None))
        key = state.rng_key

        if hasattr(self.pacing, "bind"):
            # event-driven pacing (repro.sim.driver): hand the kernel the
            # plan, masters, and current wall clock before the first
            # round — after resume, so restored clocks are not clobbered
            self.pacing.bind(ctx, plan, state)
        if self.faults is not None:
            self.faults.bind(ctx, plan, state)

        history: list[dict] = []
        wall = ledger.wall_clock_s
        for r in range(state.round_idx, R):
            t_round = wall
            if obs is not None:
                obs.round_start(r, wall)
                obs.phase_start("select+upload")
            if self.faults is not None:
                # apply every fault due by this round boundary (outages
                # arm the transport gate, crashes mark members down,
                # master failures re-elect BEFORE uploads route)
                self.faults.poll(ctx, plan, state, wall)
            self.pacing.begin_round(ctx, r)
            barriers: list[float] = []
            sels: list[RoundSelection] = []
            subs = []
            for kc, c in enumerate(plan.clusters):
                sel, state.skip_states[kc] = self.selection.select(
                    ctx, c, state.skip_states[kc], r)
                if self.faults is not None:
                    # skip-many: crashed members forced out of the mask
                    # (they idle the barrier like Skip-One'd members)
                    # with fairness carryover on the Skip-One counters
                    self.faults.apply_selection(ctx, sel,
                                                state.skip_states[kc],
                                                kc, wall)
                sels.append(sel)
                if obs is not None:
                    obs.select(r, kc, sel)
                key, sub = jax.random.split(key)
                subs.append(sub)
                barriers.append(self.pacing.account_cluster(ctx, sel, kc))
                self.mixing.upload(ctx, plan, state, kc, sel.participants,
                                   t_round)

            if obs is not None:
                obs.phase_end("select+upload")
                obs.phase_start("train")
            stacked = self._train_round(state, sels, subs, r)
            round_barrier = self.pacing.advance(barriers)
            if obs is not None:
                obs.phase_end("train", sim_dur=round_barrier)
                obs.phase_start("mix")
            stacked, dt_comm = self.mixing.mix(
                ctx, plan, state, stacked, N_k, sels, r,
                t_round, wall + round_barrier)
            if obs is not None:
                obs.phase_end("mix", sim_t0=wall + round_barrier,
                              sim_dur=dt_comm)

            state.cluster_models = stacked
            state.round_idx = r + 1
            state.rng_key = key
            state.rng_state = self.rng.bit_generator.state
            state.pacing_state = (self.pacing.state_dict()
                                  if hasattr(self.pacing, "state_dict")
                                  else None)
            state.faults_state = (self.faults.state_dict()
                                  if self.faults is not None else None)
            wall += round_barrier
            wall += dt_comm
            ledger.wall_clock_s = wall
            if obs is not None:
                obs.round_end(r, wall, wall - t_round)

            if ckpt_dir is not None and (r + 1) % ckpt_every == 0:
                from repro.ckpt import save_session
                save_session(state, os.path.join(ckpt_dir, f"step_{r + 1}"))

            if eval_fn is not None and ((r + 1) % eval_every == 0
                                        or r + 1 == R):
                if obs is not None:
                    obs.phase_start("eval")
                w_glob = crossagg.consolidate(stacked, N_k)
                m = eval_fn(w_glob, r)
                m["round"] = r
                m.update(ledger.row())
                history.append(m)
                if obs is not None:
                    obs.phase_end("eval")

        if self.faults is not None:
            # flush the fault timeline to the final wall clock (pending
            # recoveries land in the trace; faults beyond stay queued in
            # the kernel and ride any checkpoint)
            self.faults.poll(ctx, plan, state, wall)
        if obs is not None:
            obs.phase_start("finalize")
        w_final = self.mixing.finalize(ctx, plan, state, N_k, wall)
        if obs is not None:
            obs.phase_end("finalize")
            obs.session_end(ledger.wall_clock_s, ledger)
        return w_final, ledger, history
