"""RoundEngine: the one orchestrator behind CroSatFL, all five
baselines, and the scenario zoo (DESIGN.md §7-8).

Owns the canonical edge-round skeleton —

    for each round:
        for each training cluster:
            select participants        (SelectionPolicy)
            local-train                (model adapter)
            account train/idle         (PacingPolicy.account_cluster)
            intra-upload               (MixingPolicy.upload)
        fold fresh cluster models      (PacingPolicy.merge)
        mix cluster models             (MixingPolicy.mix)
        advance wall clock             (PacingPolicy.advance), evaluate

— plus session endpoints (bootstrap / finalize) and checkpoint-resume.

Uniform accounting rule (paper §III-B/C), under the default SyncPacing,
per cluster per round:

    barrier   = max realized train time over participants
    energy   += sum of participant train energy x codec arith_scale
    waiting  += sum over members of (barrier - work_i)
                (participants idle for barrier - t_i; Skip-One'd members
                do no work and idle the full barrier)

Every algorithm gets exactly this rule — accounting drift between
implementations (the pre-refactor failure mode) is impossible by
construction. Semi-sync / async pacing policies replace the barrier with
a deadline / staleness-weighted merge but keep the same invariants
(pacing.py).

Checkpoint-resume is bit-reproducible: ``SessionState`` carries both the
JAX ``rng_key`` and the host numpy bit-generator state (``rng_state`` —
selection jitter, cross-agg group sampling and top-m noise all draw from
the host RNG), so a resumed session replays the uninterrupted ledger and
weights exactly.
"""
from __future__ import annotations

import os
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import crossagg
from repro.core.energy import GPU, EnergyLedger, e_train, t_train
from repro.fl.engine.base import (ClusterPlan, EngineConfig, EngineContext,
                                  RoundSelection, SessionState)
from repro.fl.engine.costs import resolve_c_flop
from repro.fl.engine.pacing import SyncPacing, _charge_train
from repro.fl.engine.transport import IdentityCodec, Transport


def _hw_penalty(hw: np.ndarray) -> np.ndarray:
    """H_i: rare hardware is expensive to skip (Eq. 33)."""
    frac_gpu = hw.mean()
    rare_gpu = 1.0 - frac_gpu
    return np.where(hw == GPU, rare_gpu, frac_gpu)


class RoundEngine:
    """One federated session = policies x engine over (env, model).

    ``env`` duck-type (constellation/sim.py provides it):
        n_clients, profiles, n_samples, link_params, fanout,
        lisl_distance(i, j, t), master_reach(masters, t),
        gs_window_wait(sat, t), constellation
    ``model`` duck-type (fl/client.py):
        init(key) -> params
        cluster_round(w, participant_ids, n_samples, epochs, key) -> w'
        stack(list_of_params) / unstack(stacked, K)
    """

    def __init__(self, cfg: EngineConfig, env, model, *, clustering,
                 selection, mixing, codec=None, pacing=None,
                 name: str = "engine"):
        cfg = resolve_c_flop(cfg)
        self.cfg, self.env, self.model = cfg, env, model
        self.clustering, self.selection, self.mixing = \
            clustering, selection, mixing
        self.codec = codec if codec is not None else IdentityCodec()
        self.pacing = pacing if pacing is not None else SyncPacing()
        self.name = name
        self.rng = np.random.default_rng(cfg.seed)

        alpha = np.array([p.alpha for p in env.profiles])
        hw = np.array([p.hw_type for p in env.profiles])
        self._alpha, self._hw = alpha, hw

    def _make_ctx(self, ledger: EnergyLedger) -> EngineContext:
        cfg, env = self.cfg, self.env
        return EngineContext(
            cfg=cfg, env=env, model=self.model,
            transport=Transport(ledger, env.link_params, cfg.model_bits,
                                self.codec),
            rng=self.rng,
            tt_full=t_train(env.n_samples, cfg.c_flop, self._alpha,
                            cfg.local_epochs),
            et_full=e_train(env.n_samples, cfg.c_flop, env.profiles,
                            cfg.local_epochs),
            hw_penalty=_hw_penalty(self._hw))

    # -- uniform per-cluster accounting --------------------------------------
    @staticmethod
    def _account_train(ctx: EngineContext, sel: RoundSelection,
                       kc: Optional[int] = None) -> float:
        """The sync train/idle rule (kept as the engine's canonical
        reference; SyncPacing delegates here via pacing._charge_train)."""
        return _charge_train(ctx, sel, kc)

    # -- session -------------------------------------------------------------
    def run(self, rounds: Optional[int] = None,
            eval_fn: Optional[Callable] = None,
            state: Optional[SessionState] = None,
            ckpt_dir: Optional[str] = None,
            ckpt_every: int = 1,
            ):
        cfg, env, model = self.cfg, self.env, self.model
        R = rounds if rounds is not None else cfg.rounds
        key = jax.random.PRNGKey(cfg.seed)

        ledger = state.ledger if state is not None else EnergyLedger()
        ctx = self._make_ctx(ledger)
        plan, key = self.clustering.build(ctx, key)
        ctx.transport.bind_clusters(plan, env)
        K = plan.n_clusters
        N_k = np.array([env.n_samples[c].sum() for c in plan.clusters],
                       np.float64)

        if state is None:
            key, sub = jax.random.split(key)
            w0 = model.init(sub)
            masters = (plan.masters if plan.masters is not None
                       else np.zeros(0, int))
            state = SessionState(
                round_idx=0, cluster_models=model.stack([w0] * K),
                skip_states=[self.selection.init_state(len(c))
                             for c in plan.clusters],
                masters=masters, rng_key=key, ledger=ledger)
            self.mixing.bootstrap(ctx, plan, state)
            state.rng_state = self.rng.bit_generator.state
        elif state.rng_state is not None:
            # resume: restore the host RNG mid-stream, or selection jitter /
            # group sampling silently diverge from the uninterrupted run
            self.rng.bit_generator.state = state.rng_state
        key = state.rng_key

        history: list[dict] = []
        wall = ledger.wall_clock_s
        for r in range(state.round_idx, R):
            t_round = wall
            self.pacing.begin_round(ctx, r)
            barriers: list[float] = []
            sels: list[RoundSelection] = []
            new_models = []
            models_list = model.unstack(state.cluster_models, K)
            for kc, (c, w_k) in enumerate(zip(plan.clusters, models_list)):
                sel, state.skip_states[kc] = self.selection.select(
                    ctx, c, state.skip_states[kc], r)
                sels.append(sel)
                part = sel.participants
                key, sub = jax.random.split(key)
                new_models.append(model.cluster_round(
                    w_k, part, env.n_samples[part], cfg.local_epochs, sub))
                barriers.append(self.pacing.account_cluster(ctx, sel, kc))
                self.mixing.upload(ctx, plan, state, kc, part, t_round)

            stacked = self.pacing.merge(ctx, model, state, new_models,
                                        sels, r)
            round_barrier = self.pacing.advance(barriers)
            stacked, dt_comm = self.mixing.mix(
                ctx, plan, state, stacked, N_k, sels, r,
                t_round, wall + round_barrier)

            state.cluster_models = stacked
            state.round_idx = r + 1
            state.rng_key = key
            state.rng_state = self.rng.bit_generator.state
            wall += round_barrier
            wall += dt_comm
            ledger.wall_clock_s = wall

            if ckpt_dir is not None and (r + 1) % ckpt_every == 0:
                from repro.ckpt import save_session
                save_session(state, os.path.join(ckpt_dir, f"step_{r + 1}"))

            if eval_fn is not None:
                w_glob = crossagg.consolidate(stacked, N_k)
                m = eval_fn(w_glob, r)
                m["round"] = r
                m.update(ledger.row())
                history.append(m)

        w_final = self.mixing.finalize(ctx, plan, state, N_k, wall)
        return w_final, ledger, history
