"""ClusteringPolicy implementations (DESIGN.md §7).

* ``StarMaskClustering``   — the paper's RL clustering with action masking
  (CroSatFL): training clusters == communication clusters, masters by
  fan-out.
* ``SingleCluster``        — one global training cluster (GS-centric
  FedSyn / FedSCS / FedOrbit).
* ``PerPlaneGroups``       — one global model, but per-orbital-plane
  propagation chains as the communication topology (FedLEO).
* ``GreedyFanoutGroups``   — one global model with greedy optical-LISL
  neighborhoods and per-neighborhood heads (FELLO).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.core.energy import e_lisl
from repro.core.starmask import (Instance, StarMaskParams,
                                 cluster as starmask_cluster)
from repro.fl.engine.base import ClusterPlan, EngineContext


class StarMaskClustering:
    """Paper §IV-A: StarMask over satellite profiles + LISL feasibility."""

    def __init__(self, params: StarMaskParams,
                 policy_params: Optional[dict] = None):
        self.params = params
        self.policy_params = policy_params

    def make_instance(self, ctx: EngineContext) -> Instance:
        env, cfg = ctx.env, ctx.cfg
        n = env.n_clients
        lisl_e = np.zeros((n, n))
        for i in range(n):
            for j in range(n):
                dist = env.lisl_distance(i, j, 0.0)
                lisl_e[i, j] = (e_lisl(cfg.model_bits,
                                       env.link_params.lisl_rate,
                                       dist, env.link_params)
                                if np.isfinite(dist) else 1e9)
        return Instance(
            share=env.n_samples / env.n_samples.sum(),
            hw=np.array([p.hw_type for p in env.profiles]),
            t_comp=ctx.tt_full / cfg.local_epochs,
            e_train=ctx.et_full,
            fanout=np.asarray(env.fanout),
            lisl_e=lisl_e,
        )

    def build(self, ctx: EngineContext, key):
        inst = self.make_instance(ctx)
        key, sub = jax.random.split(key)
        result = starmask_cluster(inst, self.params, sub,
                                  params=self.policy_params)
        assert result.feasible, f"StarMask infeasible, K_min={result.k_min}"
        clusters = result.clusters
        masters = np.array([c[np.argmax(inst.fanout[c])] for c in clusters])
        plan = ClusterPlan(clusters=clusters, masters=masters,
                           meta={"instance": inst, "result": result})
        return plan, key


class SingleCluster:
    """All clients train one global model (GS-centric baselines)."""

    def build(self, ctx: EngineContext, key):
        return ClusterPlan(clusters=[np.arange(ctx.env.n_clients)]), key


class PerPlaneGroups(SingleCluster):
    """FedLEO: clients grouped by orbital plane into propagation chains;
    singleton planes merge into neighbors until each chain has >= 3."""

    def build(self, ctx: EngineContext, key):
        plan, key = super().build(ctx, key)
        env = ctx.env
        planes = env.constellation.plane_of(env.sat_ids)
        groups = [np.flatnonzero(planes == p) for p in np.unique(planes)]
        merged, cur = [], []
        for g in groups:
            cur = np.concatenate([cur, g]).astype(int) if len(cur) else g
            if len(cur) >= 3:
                merged.append(cur)
                cur = []
        if len(cur):
            merged.append(cur)
        plan.comm_groups = merged
        return plan, key


class GreedyFanoutGroups(SingleCluster):
    """FELLO: greedy geographic clustering into optical-LISL-feasible
    neighborhoods, highest-fan-out member as head."""

    def __init__(self, n_clusters: int = 9):
        self.n_clusters = n_clusters

    def build(self, ctx: EngineContext, key):
        plan, key = super().build(ctx, key)
        env = ctx.env
        n_clusters = max(1, min(self.n_clusters, env.n_clients // 2))
        order = np.argsort(-env.fanout)
        groups = [order[i::n_clusters] for i in range(n_clusters)]
        plan.comm_groups = groups
        plan.heads = np.array([int(c[np.argmax(env.fanout[c])])
                               for c in groups])
        return plan, key
