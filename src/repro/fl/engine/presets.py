"""Policy quadruples for the paper's six algorithms (DESIGN.md §7).

Each algorithm is ~a handful of lines here: pick a clustering, a
selection, a mixing policy and (optionally) a payload codec, and hand them
to the shared ``RoundEngine``. Adding an FL variant means writing a new
policy, not a new loop.
"""
from __future__ import annotations

from typing import Optional

from repro.core.skipone import SkipOneParams
from repro.core.starmask import StarMaskParams
from repro.fl.engine.base import EngineConfig
from repro.fl.engine.clustering import (GreedyFanoutGroups, PerPlaneGroups,
                                        SingleCluster, StarMaskClustering)
from repro.fl.engine.engine import RoundEngine
from repro.fl.engine.mixing import (CrossAggMixing, GSStarMixing,
                                    HeadChainMixing, RelayedGSStarMixing,
                                    SinkChainMixing)
from repro.fl.engine.selection import (AllParticipate, SkipOneSelection,
                                       TopMEnergyUtility)
from repro.fl.engine.transport import BlockMinifloatCodec


def make_crosatfl(cfg: EngineConfig, env, model, *,
                  k_nbr: int = 2,
                  skip_one: Optional[SkipOneParams] = None,
                  starmask: Optional[StarMaskParams] = None,
                  policy_params: Optional[dict] = None) -> RoundEngine:
    """CroSatFL = StarMask clustering x Skip-One x random-k cross-agg."""
    return RoundEngine(
        cfg, env, model,
        clustering=StarMaskClustering(starmask or StarMaskParams(),
                                      policy_params=policy_params),
        selection=SkipOneSelection(skip_one or SkipOneParams()),
        mixing=CrossAggMixing(k_nbr=k_nbr),
        name="CroSatFL")


def make_baseline(name: str, cfg: EngineConfig, env, model, *,
                  select_m: int = 16, minifloat_bits: int = 12,
                  arith_scale: float = 0.5,
                  n_clusters: int = 9) -> RoundEngine:
    """The five comparison baselines (paper §V-A) as policy quadruples.

      FedSyn   = single cluster x all x GS star
      FedLEO   = per-plane chains x all x sink-chain
      FELLO    = greedy fan-out heads x all x head-chain
      FedSCS   = single cluster x top-m utility x relayed GS star
      FedOrbit = FedSCS x block-minifloat codec
    """
    if name == "FedSyn":
        policies = dict(clustering=SingleCluster(),
                        selection=AllParticipate(),
                        mixing=GSStarMixing())
    elif name == "FedLEO":
        policies = dict(clustering=PerPlaneGroups(),
                        selection=AllParticipate(),
                        mixing=SinkChainMixing())
    elif name == "FELLO":
        policies = dict(clustering=GreedyFanoutGroups(n_clusters=n_clusters),
                        selection=AllParticipate(),
                        mixing=HeadChainMixing())
    elif name == "FedSCS":
        policies = dict(clustering=SingleCluster(),
                        selection=TopMEnergyUtility(select_m=select_m),
                        mixing=RelayedGSStarMixing())
    elif name == "FedOrbit":
        policies = dict(clustering=SingleCluster(),
                        selection=TopMEnergyUtility(select_m=select_m),
                        mixing=RelayedGSStarMixing(),
                        codec=BlockMinifloatCodec(bits=minifloat_bits,
                                                  arith_scale=arith_scale))
    else:
        raise KeyError(f"unknown baseline {name!r}")
    return RoundEngine(cfg, env, model, name=name, **policies)


BASELINE_NAMES = ("FedSyn", "FedLEO", "FELLO", "FedSCS", "FedOrbit")
