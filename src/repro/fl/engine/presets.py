"""Policy quadruples for the paper's six algorithms (DESIGN.md §7).

Each algorithm is ~a handful of lines here: pick a clustering, a
selection, a mixing policy and (optionally) a payload codec, and hand them
to the shared ``RoundEngine``. Adding an FL variant means writing a new
policy, not a new loop.
"""
from __future__ import annotations

from typing import Optional

from repro.core.skipone import SkipOneParams
from repro.core.starmask import StarMaskParams
from repro.fl.engine.base import EngineConfig
from repro.fl.engine.clustering import (GreedyFanoutGroups, PerPlaneGroups,
                                        SingleCluster, StarMaskClustering)
from repro.fl.engine.engine import RoundEngine
from repro.fl.engine.mixing import (CrossAggMixing, GossipMixing,
                                    GSStarMixing, HeadChainMixing,
                                    RelayedGSStarMixing, SinkChainMixing)
from repro.fl.engine.pacing import AsyncPacing, SemiSyncPacing
from repro.fl.engine.selection import (AllParticipate, SkipOneSelection,
                                       TopMEnergyUtility)
from repro.fl.engine.transport import (BlockMinifloatCodec,
                                       HardwareAwareCodecMap)


def make_crosatfl(cfg: EngineConfig, env, model, *,
                  k_nbr: int = 2,
                  skip_one: Optional[SkipOneParams] = None,
                  starmask: Optional[StarMaskParams] = None,
                  policy_params: Optional[dict] = None,
                  mixing=None, pacing=None, codec=None,
                  mixing_backend: Optional[str] = None,
                  name: str = "CroSatFL", observer=None,
                  faults=None) -> RoundEngine:
    """CroSatFL = StarMask clustering x Skip-One x random-k cross-agg.

    ``mixing``/``pacing``/``codec`` override single policies for scenario
    variants (see ``make_scenario``) while keeping the CroSatFL quadruple
    as the base. ``mixing_backend="pallas"`` keeps the default
    CrossAggMixing policy but routes its contraction through the fused
    Pallas cross_agg kernel (ignored when ``mixing`` is given).
    ``observer`` attaches an ``EngineObserver`` (repro.obs) to the session.
    ``faults`` attaches a ``repro.faults`` ``FaultSchedule`` /
    ``FaultInjector`` (None = the fault-free golden path).
    """
    return RoundEngine(
        cfg, env, model,
        clustering=StarMaskClustering(starmask or StarMaskParams(),
                                      policy_params=policy_params),
        selection=SkipOneSelection(skip_one or SkipOneParams()),
        mixing=mixing if mixing is not None else CrossAggMixing(
            k_nbr=k_nbr, backend=mixing_backend or "einsum"),
        pacing=pacing, codec=codec,
        name=name, observer=observer, faults=faults)


def make_baseline(name: str, cfg: EngineConfig, env, model, *,
                  select_m: int = 16, minifloat_bits: int = 12,
                  arith_scale: float = 0.5,
                  n_clusters: int = 9, observer=None,
                  faults=None) -> RoundEngine:
    """The five comparison baselines (paper §V-A) as policy quadruples.

      FedSyn   = single cluster x all x GS star
      FedLEO   = per-plane chains x all x sink-chain
      FELLO    = greedy fan-out heads x all x head-chain
      FedSCS   = single cluster x top-m utility x relayed GS star
      FedOrbit = FedSCS x block-minifloat codec
    """
    if name == "FedSyn":
        policies = dict(clustering=SingleCluster(),
                        selection=AllParticipate(),
                        mixing=GSStarMixing())
    elif name == "FedLEO":
        policies = dict(clustering=PerPlaneGroups(),
                        selection=AllParticipate(),
                        mixing=SinkChainMixing())
    elif name == "FELLO":
        policies = dict(clustering=GreedyFanoutGroups(n_clusters=n_clusters),
                        selection=AllParticipate(),
                        mixing=HeadChainMixing())
    elif name == "FedSCS":
        policies = dict(clustering=SingleCluster(),
                        selection=TopMEnergyUtility(select_m=select_m),
                        mixing=RelayedGSStarMixing())
    elif name == "FedOrbit":
        policies = dict(clustering=SingleCluster(),
                        selection=TopMEnergyUtility(select_m=select_m),
                        mixing=RelayedGSStarMixing(),
                        codec=BlockMinifloatCodec(bits=minifloat_bits,
                                                  arith_scale=arith_scale))
    else:
        raise KeyError(f"unknown baseline {name!r}")
    return RoundEngine(cfg, env, model, name=name, observer=observer,
                       faults=faults, **policies)


BASELINE_NAMES = ("FedSyn", "FedLEO", "FELLO", "FedSCS", "FedOrbit")


def make_scenario(name: str, cfg: EngineConfig, env, model, *,
                  k_nbr: int = 2,
                  skip_one: Optional[SkipOneParams] = None,
                  starmask: Optional[StarMaskParams] = None,
                  observer=None, faults=None, **kw) -> RoundEngine:
    """Scenario-zoo presets (DESIGN.md §8): CroSatFL's policy quadruple
    with ONE surface swapped — each scenario is a policy, not a loop.

      CroSatFL-SemiSync    = CroSatFL x deadline pacing (stragglers'
                             late updates fold into the next mix)
      CroSatFL-Async       = CroSatFL x staleness-weighted async merge
                             (FedAsync-style; wall clock = mean cycle)
      CroSatFL-Gossip      = CroSatFL x gossip-only mixing (no GS at all:
                             LISL-flood bootstrap, consensus finalize)
      CroSatFL-HeteroCodec = CroSatFL x per-cluster codec map
                             (block-minifloat on CPU-heavy clusters,
                             identity on GPU clusters)
      CroSatFL-EventSync   = CroSatFL x sync pacing REPLAYED through the
                             discrete-event kernel (repro.sim; golden
                             ledger bit-parity by construction)
      CroSatFL-EventAsync  = CroSatFL x event-driven async: true
                             per-cluster clocks, merges fire on LISL
                             availability, sim-time staleness weights
      CroSatFL-EventAsyncGeo = EventAsync with commits additionally
                             staggered by the slant-range transfer
                             duration over the master-to-master LISL
                             (``geom_transfer=True``)

    ``**kw`` feeds the swapped policy's constructor (e.g. ``quantile``,
    ``alpha0``, ``consensus_eps``, ``cpu_threshold``).
    """
    base = dict(k_nbr=k_nbr, skip_one=skip_one, starmask=starmask,
                name=name, observer=observer, faults=faults)
    if name == "CroSatFL-SemiSync":
        return make_crosatfl(cfg, env, model,
                             pacing=SemiSyncPacing(**kw), **base)
    if name == "CroSatFL-Async":
        return make_crosatfl(cfg, env, model,
                             pacing=AsyncPacing(**kw), **base)
    if name == "CroSatFL-Gossip":
        return make_crosatfl(cfg, env, model,
                             mixing=GossipMixing(k_nbr=k_nbr, **kw), **base)
    if name == "CroSatFL-HeteroCodec":
        return make_crosatfl(cfg, env, model,
                             codec=HardwareAwareCodecMap(**kw), **base)
    if name in ("CroSatFL-EventSync", "CroSatFL-EventAsync",
                "CroSatFL-EventAsyncGeo"):
        # lazy import: repro.sim.driver imports this package's pacing
        # module, so a top-level import here would be circular
        from repro.sim.driver import EventAsyncPacing, EventDrivenPacing
        kw.setdefault("seed", cfg.seed)
        if name == "CroSatFL-EventSync":
            pacing = EventDrivenPacing(**kw)
        elif name == "CroSatFL-EventAsyncGeo":
            pacing = EventAsyncPacing(geom_transfer=True, **kw)
        else:
            pacing = EventAsyncPacing(**kw)
        return make_crosatfl(cfg, env, model, pacing=pacing, **base)
    raise KeyError(f"unknown scenario {name!r}")


SCENARIO_NAMES = ("CroSatFL-SemiSync", "CroSatFL-Async", "CroSatFL-Gossip",
                  "CroSatFL-HeteroCodec", "CroSatFL-EventSync",
                  "CroSatFL-EventAsync", "CroSatFL-EventAsyncGeo")
