"""SelectionPolicy implementations (DESIGN.md §7).

Each policy draws this round's transient-load jitter itself (lognormal
sigma=0.25 over the engaged members, from the shared host RNG) so realized
runtimes feed selection where the algorithm calls for it (Skip-One) and
follow it where it doesn't (top-m utility).
"""
from __future__ import annotations

import numpy as np

from repro.core import skipone
from repro.fl.engine.base import EngineContext, RoundSelection

JITTER_SIGMA = 0.25


class AllParticipate:
    """Everyone trains every round (FedSyn / FedLEO / FELLO)."""

    def init_state(self, n_members: int):
        return None

    def select(self, ctx: EngineContext, members: np.ndarray, state,
               round_idx: int):
        jitter = ctx.rng.lognormal(0.0, JITTER_SIGMA, len(members))
        tt_r = ctx.tt_full[members] * jitter
        return RoundSelection(members, np.ones(len(members), bool),
                              tt_r), state


class SkipOneSelection:
    """Paper §IV-B (Eq. 26-33): skip at most one satellite per cluster per
    round under the fairness-constrained utility."""

    def __init__(self, params: skipone.SkipOneParams):
        self.params = params

    def init_state(self, n_members: int):
        return skipone.SkipOneState.init(n_members)

    def select(self, ctx: EngineContext, members: np.ndarray, state,
               round_idx: int):
        jitter = ctx.rng.lognormal(0.0, JITTER_SIGMA, len(members))
        tt_r = ctx.tt_full[members] * jitter
        mask, state = skipone.select(tt_r, ctx.et_full[members],
                                     ctx.hw_penalty[members], state,
                                     self.params, round_idx)
        return RoundSelection(members, mask, tt_r), state


class TopMEnergyUtility:
    """FedSCS-style energy-aware client selection: top-m by a noised
    energy/latency utility (the original uses a knapsack-style utility);
    the noise rotates participation across rounds."""

    def __init__(self, select_m: int = 16):
        self.select_m = select_m

    def init_state(self, n_members: int):
        return None

    def select(self, ctx: EngineContext, members: np.ndarray, state,
               round_idx: int):
        et, tt = ctx.et_full[members], ctx.tt_full[members]
        util = -et / et.max() - 0.5 * tt / tt.max()
        noise = ctx.rng.normal(0, 0.1, len(util))
        part = members[np.argsort(-(util + noise))[: self.select_m]]
        jitter = ctx.rng.lognormal(0.0, JITTER_SIGMA, len(part))
        tt_r = ctx.tt_full[part] * jitter
        return RoundSelection(part, np.ones(len(part), bool), tt_r), state
