"""Round-engine core types and policy protocols (DESIGN.md §7).

One orchestrator — ``RoundEngine`` (engine.py) — owns the canonical edge
round skeleton shared by CroSatFL and every baseline:

    select → local-train → intra-upload → mix → account

and composes five small policy surfaces:

* ``ClusteringPolicy``  — who trains together, and over which
  communication topology (StarMask, per-plane chains, greedy fan-out
  clusters, or a single GS-centric cluster).
* ``SelectionPolicy``   — which cluster members train this round
  (Skip-One, everyone, top-m energy utility).
* ``MixingPolicy``      — how models move between rounds (random-k
  cross-aggregation, GS star, sink chains, head chains, gossip-only) plus
  the session endpoints (bootstrap distribution, final collection).
* ``PacingPolicy``      — how per-cluster completion times fold into a
  round (sync barrier, semi-sync deadline, async staleness-weighted
  merge; pacing.py).
* ``Transport``         — the ONE place GS/LISL energy+latency enter the
  ``EnergyLedger`` (transport.py), parameterized by a ``PayloadCodec``
  (engine-global) or a ``CodecMap`` (heterogeneous per cluster).

Every algorithm in the repo is a (clustering, selection, mixing, codec)
quadruple over the same engine — scenario presets additionally pick a
pacing policy (presets.py) — so Table-II comparisons are guaranteed to
use identical accounting by construction.

All protocols are duck-typed; the classes below document the contract.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Protocol

import numpy as np

from repro.core.energy import EnergyLedger


@dataclass(frozen=True)
class EngineConfig:
    """Algorithm-independent knobs; policy-specific parameters live on the
    policy objects themselves.

    ``c_flop`` may be a float (FLOPs per sample) or a ``"measured:"`` spec
    resolved against compiled-HLO dry-run estimates (launch/measured.py),
    e.g. ``"measured:gemma3-1b/train_4k"``.

    ``executor`` selects HOW a round's local training runs
    (repro.fl.exec, DESIGN.md §12): "sequential" (default; the golden
    bit-parity reference), "batched" (cluster models stay stacked
    end-to-end and ONE nested-vmap fleet call trains every participant of
    every cluster), "sharded" (the batched call with the fleet tensor
    cluster-pod-sharded across devices via repro.dist), or an
    ``Executor`` instance. The batched/sharded paths are
    tolerance-pinned against sequential; the ledger is bit-equal across
    all three by construction.

    ``batched_exec`` is the DEPRECATED bool predecessor of ``executor``;
    it still maps to the batched path (with its old silent sequential
    fallback for models without a fleet surface) via a shim in
    ``repro.fl.exec.resolve_executor``, which warns.

    ``aggregator`` selects the Byzantine-robust merge estimator
    (repro.fl.robust, DESIGN.md §14): "fedavg" (default; identity
    pass-through — bit-parity with the historical merges), "median",
    "trimmed_mean", "norm_clip", "krum", or a ``RobustAggregator``
    instance. ``quorum`` gates each cluster's commit on a minimum
    fraction of valid delivered updates: None (off, the default), a
    min-fraction float, or a ``QuorumPolicy`` instance.

    ``retry_base_s`` / ``retry_max_attempts`` override the Transport
    retry policy under faults (base backoff seconds of the
    ``base * 2^attempt`` schedule / the attempt cap). ``None`` (default)
    keeps the attached ``FaultSchedule``'s knobs — golden ledgers stay
    bit-for-bit.
    """
    rounds: int = 40
    local_epochs: int = 10
    c_flop: Any = 5e7
    model_bits: float = 8 * 44.7e6
    seed: int = 0
    batched_exec: bool = False
    executor: Any = None
    aggregator: Any = "fedavg"
    quorum: Any = None
    retry_base_s: Optional[float] = None
    retry_max_attempts: Optional[int] = None


@dataclass
class ClusterPlan:
    """Output of a ClusteringPolicy.

    ``clusters`` are the TRAINING clusters (each holds one model between
    mixes). ``comm_groups``/``heads`` describe the communication topology
    when it differs from the training partition (FedLEO planes, FELLO
    optical neighborhoods); GS-centric algorithms train one global model
    (a single cluster) while routing updates through their native groups.
    """
    clusters: list[np.ndarray]
    masters: Optional[np.ndarray] = None          # (K,) master client ids
    comm_groups: Optional[list[np.ndarray]] = None
    heads: Optional[np.ndarray] = None            # per-comm-group head ids
    meta: dict = field(default_factory=dict)

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)


@dataclass
class RoundSelection:
    """Output of a SelectionPolicy for one (cluster, round).

    ``ids`` are the engaged member ids; ``mask[i]`` is True when ids[i]
    trains (False → Skip-One'd: idles at the barrier, latency only);
    ``tt_r`` is the jittered realized train time per engaged member.
    """
    ids: np.ndarray
    mask: np.ndarray
    tt_r: np.ndarray

    @property
    def participants(self) -> np.ndarray:
        return self.ids[self.mask]


@dataclass
class SessionState:
    """Everything needed to restart mid-session (ckpt/ serializes this).

    Field names are frozen: ckpt/store.py round-trips them and
    core.session re-exports the class for callers of the legacy API.
    ``skip_states`` holds the SelectionPolicy's per-cluster state (Skip-One
    fairness counters for CroSatFL; None entries for stateless policies).
    ``rng_state`` is the host numpy bit-generator state captured at the
    same round boundary as ``rng_key`` — both RNG streams must round-trip
    or a resumed session diverges from the uninterrupted one (selection
    jitter, cross-agg group sampling and top-m noise are host-side).
    ``None`` on checkpoints written before this field existed; the engine
    then resumes with a freshly seeded host RNG (the pre-fix behavior).
    ``pacing_state`` carries the PacingPolicy's exportable cross-round
    state (``state_dict()``) captured at the same boundary — today that is
    ``SemiSyncPacing``'s straggler stash, so a semi-sync disk resume is
    exact even with a deferred update pending (DESIGN.md §8); ``None`` for
    stateless policies and on older checkpoints.
    ``faults_state`` is the attached ``FaultInjector``'s snapshot (its
    kernel — pending future fault events included — plus the live
    outage/crash view; DESIGN.md §13): a mid-campaign resume replays the
    uninterrupted fault timeline bit-for-bit. ``None`` when no schedule
    is attached and on older checkpoints.
    """
    round_idx: int
    cluster_models: Any              # stacked (K, ...) pytree
    skip_states: list
    masters: np.ndarray              # (K,) current master satellite ids
    rng_key: Any
    ledger: EnergyLedger
    rng_state: Any = None            # np Generator.bit_generator.state dict
    pacing_state: Any = None         # PacingPolicy.state_dict() snapshot
    faults_state: Any = None         # FaultInjector.state_dict() snapshot


@dataclass
class EngineContext:
    """Read-mostly bundle threaded through policies each call.

    ``obs`` is the session's ``EngineObserver`` (repro.obs.observer) or
    ``None`` when observability is disabled — every hook site guards with
    ``if ctx.obs is not None`` so the disabled path costs one pointer
    comparison and the golden ledgers stay bit-for-bit (DESIGN.md §10).

    ``robust``/``quorum`` are the resolved ``RobustAggregator`` /
    ``QuorumPolicy`` (repro.fl.robust, DESIGN.md §14) every pacing merge
    routes through; the fedavg/None defaults make ``apply_robustness`` a
    pass-through after two attribute reads.
    """
    cfg: EngineConfig
    env: Any
    model: Any
    transport: Any                   # transport.Transport
    rng: np.random.Generator         # host RNG shared by all policies
    tt_full: np.ndarray              # (n,) per-round train seconds
    et_full: np.ndarray              # (n,) per-round train joules
    hw_penalty: np.ndarray           # (n,) Skip-One hardware-rarity term
    obs: Any = None                  # EngineObserver | None
    robust: Any = None               # RobustAggregator | None
    quorum: Any = None               # QuorumPolicy | None

    @property
    def ledger(self) -> EnergyLedger:
        return self.transport.ledger


# ---------------------------------------------------------------------------
# Protocols (documentation of the duck-type; not enforced at runtime)
# ---------------------------------------------------------------------------

class ClusteringPolicy(Protocol):
    def build(self, ctx: EngineContext, key) -> tuple[ClusterPlan, Any]:
        """Partition clients; may consume PRNG splits from ``key``."""
        ...


class SelectionPolicy(Protocol):
    def init_state(self, n_members: int) -> Any:
        """Per-cluster fairness state (None for stateless policies)."""
        ...

    def select(self, ctx: EngineContext, members: np.ndarray, state: Any,
               round_idx: int) -> tuple[RoundSelection, Any]:
        """Draw this round's participants (and their realized runtimes)."""
        ...


class PacingPolicy(Protocol):
    """How per-cluster completion times fold into a round (pacing.py):
    sync barrier, semi-sync deadline, or fully-async staleness-weighted
    merge. The engine calls the four hooks in this order every round so
    barrier/wait accounting stays in one place per policy."""

    def begin_round(self, ctx: EngineContext, round_idx: int) -> None:
        """Reset per-round pacing state."""
        ...

    def account_cluster(self, ctx: EngineContext, sel: RoundSelection,
                        kc: int) -> float:
        """Charge cluster ``kc``'s train energy (+ idle, if the policy
        can already price it); return the cluster's completion time."""
        ...

    def merge(self, ctx: EngineContext, model, state: "SessionState",
              new_models: list, sels: list, round_idx: int):
        """Fold this round's fresh cluster models into stacked models
        entering the mix (replace / defer stragglers / staleness-weight)."""
        ...

    def merge_stacked(self, ctx: EngineContext, model,
                      state: "SessionState", new_stacked, sels: list,
                      round_idx: int):
        """Stacked-pytree twin of ``merge`` for the batched execution path
        (DESIGN.md §9): same accounting and fold semantics, expressed as
        (K, ...)-leaf ops so cluster models never unstack. The engine falls
        back to ``unstack`` + ``merge`` when a policy lacks this hook."""
        ...

    def advance(self, barriers: list) -> float:
        """Round wall-clock advance from per-cluster completion times."""
        ...

    def state_dict(self):
        """Exportable cross-round state for checkpointing (``None`` when
        stateless); rides in ``SessionState.pacing_state``."""
        ...

    def load_state_dict(self, state) -> None:
        """Restore a ``state_dict()`` snapshot on session resume."""
        ...


class MixingPolicy(Protocol):
    def bootstrap(self, ctx: EngineContext, plan: ClusterPlan,
                  state: SessionState) -> None:
        """Account initial model distribution (GS bootstrap + relays)."""
        ...

    def upload(self, ctx: EngineContext, plan: ClusterPlan,
               state: SessionState, kc: int, participants: np.ndarray,
               t_now: float) -> None:
        """Account intra-cluster update collection for cluster ``kc``."""
        ...

    def mix(self, ctx: EngineContext, plan: ClusterPlan, state: SessionState,
            stacked, N_k: np.ndarray, sels: list[RoundSelection],
            round_idx: int, t_round: float, t_now: float):
        """Inter-cluster model movement. Returns (stacked', extra_wall_s)."""
        ...

    def finalize(self, ctx: EngineContext, plan: ClusterPlan,
                 state: SessionState, N_k: np.ndarray, wall: float):
        """Collect the session result. Returns the final global model."""
        ...
