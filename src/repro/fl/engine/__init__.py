"""Pluggable FL round engine: one orchestrator + five policy surfaces
(clustering / selection / mixing / pacing / transport) behind CroSatFL,
every baseline, and the scenario zoo (semi-sync & async pacing,
gossip-only sessions, per-cluster codec maps). See base.py for the
protocol contract and DESIGN.md §7-8 for the algorithm -> policy tables.
"""
from repro.fl.engine.base import (ClusterPlan, ClusteringPolicy,  # noqa: F401
                                  EngineConfig, EngineContext, MixingPolicy,
                                  PacingPolicy, RoundSelection,
                                  SelectionPolicy, SessionState)
from repro.fl.engine.clustering import (GreedyFanoutGroups,  # noqa: F401
                                        PerPlaneGroups, SingleCluster,
                                        StarMaskClustering)
from repro.fl.engine.costs import measured_c_flop, resolve_c_flop  # noqa: F401
from repro.fl.engine.engine import RoundEngine  # noqa: F401
from repro.fl.engine.mixing import (CrossAggMixing, GossipMixing,  # noqa: F401
                                    GSStarMixing, HeadChainMixing,
                                    RelayedGSStarMixing, SinkChainMixing)
from repro.fl.engine.pacing import (AsyncPacing, SemiSyncPacing,  # noqa: F401
                                    SyncPacing)
from repro.fl.engine.presets import (BASELINE_NAMES, SCENARIO_NAMES,  # noqa: F401
                                     make_baseline, make_crosatfl,
                                     make_scenario)
from repro.fl.engine.selection import (AllParticipate,  # noqa: F401
                                       SkipOneSelection, TopMEnergyUtility)
from repro.fl.engine.transport import (BlockMinifloatCodec,  # noqa: F401
                                       CodecMap, HardwareAwareCodecMap,
                                       IdentityCodec, Transport)
