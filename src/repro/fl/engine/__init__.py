"""Pluggable FL round engine: one orchestrator + four policy surfaces
(clustering / selection / mixing / transport) behind CroSatFL and every
baseline. See base.py for the protocol contract and DESIGN.md §7 for the
algorithm -> policy table.
"""
from repro.fl.engine.base import (ClusterPlan, ClusteringPolicy,  # noqa: F401
                                  EngineConfig, EngineContext, MixingPolicy,
                                  RoundSelection, SelectionPolicy,
                                  SessionState)
from repro.fl.engine.clustering import (GreedyFanoutGroups,  # noqa: F401
                                        PerPlaneGroups, SingleCluster,
                                        StarMaskClustering)
from repro.fl.engine.costs import measured_c_flop, resolve_c_flop  # noqa: F401
from repro.fl.engine.engine import RoundEngine  # noqa: F401
from repro.fl.engine.mixing import (CrossAggMixing, GSStarMixing,  # noqa: F401
                                    HeadChainMixing, RelayedGSStarMixing,
                                    SinkChainMixing)
from repro.fl.engine.presets import (BASELINE_NAMES, make_baseline,  # noqa: F401
                                     make_crosatfl)
from repro.fl.engine.selection import (AllParticipate,  # noqa: F401
                                       SkipOneSelection, TopMEnergyUtility)
from repro.fl.engine.transport import (BlockMinifloatCodec,  # noqa: F401
                                       IdentityCodec, Transport)
