"""FL model adapter: local training executor + cluster-round aggregation.

Implements the ``model`` duck-type consumed by core/session.Session and
fl/baselines.py:

    init(key) -> params
    cluster_round(w, participant_ids, n_samples, epochs, key) -> w'
    local_update(w, client_id, epochs, key) -> w_i  (single client)
    stack(list[params]) / unstack(stacked, K)
    evaluate(params) -> {"acc": ..., "loss": ...}

plus the pure fleet surface consumed by the batched/sharded executors
(repro.fl.exec, DESIGN.md §12):

    init_fleet() -> {"x", "y", "m"} device pytree, leading n_clients dim
    client_step(epochs) -> fn(params, data_slice, key) -> params

Local training is one jitted call per (client, round): data is padded to a
fixed ``n_pad`` so every client shares a single compilation; padded rows
are masked out of the loss. SGD-momentum, batch size 10 (paper Table I).

The device-resident batched path (DESIGN.md §9) stacks all client data on
device once — ``(n_clients, n_pad, H, W, C)`` with row masks — and
``repro.fl.exec.batched`` trains every participant of every cluster in
one nested-vmap call over ``client_step``; ``fleet_round`` remains as a
thin delegate for callers of the pre-executor entry point. Per-participant
PRNG keys are split exactly as the sequential ``cluster_round`` splits
them, so the paths differ only by XLA scheduling (tolerance-pinned parity
in tests/test_batched_exec.py; the sequential path stays the bit-parity
reference).
"""
from __future__ import annotations

import inspect
import math
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synth import SynthImageDataset
from repro.fl.models_image import MODEL_ZOO
from repro.obs.jaxprof import annotate
from repro.optim.optimizers import sgd_init, sgd_update

F32 = jnp.float32


def _local_train_body(params, x, y, mask, key, *, apply_fn, epochs: int,
                      batch: int, lr: float, momentum: float,
                      unroll: bool = False):
    """x: (n_pad, H, W, C); mask: (n_pad,) 1.0 for real rows.

    ``unroll`` inlines both training loops (same ops, same order): under
    ``vmap`` the rolled XLA while-loops round-trip the whole
    (lanes, ...) carry every iteration, so the fleet path unrolls when
    the loop count is small; the sequential path keeps rolled loops
    (unrolling there only bloats compile time).
    """
    n_pad = x.shape[0]
    steps = n_pad // batch

    def loss_fn(p, xb, yb, mb):
        logits = apply_fn(p, xb).astype(F32)
        ce = -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                  yb[:, None], 1)[:, 0]
        return (ce * mb).sum() / jnp.maximum(mb.sum(), 1.0)

    def epoch(carry, ekey):
        p, m = carry
        perm = jax.random.permutation(ekey, n_pad)
        xs = x[perm].reshape(steps, batch, *x.shape[1:])
        ys = y[perm].reshape(steps, batch)
        ms = mask[perm].reshape(steps, batch)

        def step(carry, b):
            p, mstate = carry
            xb, yb, mb = b
            g = jax.grad(loss_fn)(p, xb, yb, mb)
            p, mstate = sgd_update(p, g, mstate, lr=lr, momentum=momentum)
            return (p, mstate), ()

        (p, m), _ = jax.lax.scan(step, (p, m), (xs, ys, ms), unroll=unroll)
        return (p, m), ()

    m0 = sgd_init(params)
    (params, _), _ = jax.lax.scan(epoch, (params, m0),
                                  jax.random.split(key, epochs),
                                  unroll=unroll)
    return params


_local_train = jax.jit(_local_train_body,
                       static_argnames=("apply_fn", "epochs", "batch", "lr",
                                        "momentum", "unroll"))

# fully unrolling epochs x steps bodies is only worth the compile cost
# while the total loop count is small (benchmark-scale rounds); past this
# the fleet path falls back to rolled loops like the sequential path
_UNROLL_LIMIT = 32


def _image_client_step(params, data, key, *, apply_fn, epochs: int,
                       batch: int, lr: float, momentum: float,
                       unroll: bool = False):
    """One client's slice of the fleet pytree through local training —
    the pure ``client_step`` body the batched/sharded executors vmap."""
    return _local_train_body(params, data["x"], data["y"], data["m"], key,
                             apply_fn=apply_fn, epochs=epochs, batch=batch,
                             lr=lr, momentum=momentum, unroll=unroll)


@partial(jax.jit, static_argnames=("apply_fn",))
def _evaluate(params, x, y, *, apply_fn):
    logits = apply_fn(params, x).astype(F32)
    pred = logits.argmax(-1)
    ce = -jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], 1)[:, 0]
    return (pred == y).mean(), ce.mean()


def fedavg(params_list: list[Any], weights: np.ndarray):
    w = jnp.asarray(weights / weights.sum(), F32)

    def avg(*leaves):
        stacked = jnp.stack([l.astype(F32) for l in leaves])
        return jnp.einsum("k,k...->...", w, stacked).astype(leaves[0].dtype)

    return jax.tree.map(avg, *params_list)


class ImageFLModel:
    def __init__(self, dataset: SynthImageDataset, partitions: list[np.ndarray],
                 test: SynthImageDataset, model: str = "small-cnn",
                 batch: int = 10, lr: float = 0.02, momentum: float = 0.9,
                 n_pad: Optional[int] = None, **model_kw):
        self.ds, self.parts, self.test = dataset, partitions, test
        self.init_fn, self.apply_fn = MODEL_ZOO[model]
        self.model_kw = dict(in_ch=dataset.x.shape[-1],
                             n_classes=dataset.n_classes, **model_kw)
        if "hw" in inspect.signature(self.init_fn).parameters:
            self.model_kw.setdefault("hw", dataset.x.shape[1])
        self.batch, self.lr, self.momentum = batch, lr, momentum
        sizes = [len(p) for p in partitions]
        self.n_pad = n_pad or batch * math.ceil(max(sizes) / batch)
        self._xt = jnp.asarray(test.x)
        self._yt = jnp.asarray(test.y.astype(np.int32))
        self._pad_cache: dict[int, tuple] = {}   # cid -> device (x, y, m)
        self._fleet_data: Optional[tuple] = None
        self._step_cache: dict[int, Any] = {}    # epochs -> client_step fn
        self._model_bits: Optional[int] = None

    # ---- duck-type ---------------------------------------------------------
    def init(self, key):
        return self.init_fn(key, **self.model_kw)

    def _padded(self, cid: int):
        """Client ``cid``'s padded data, memoized on device: repeat rounds
        reuse the same buffers instead of re-transferring identical data."""
        hit = self._pad_cache.get(cid)
        if hit is not None:
            return hit
        idx = self.parts[cid]
        n = len(idx)
        x = np.zeros((self.n_pad,) + self.ds.x.shape[1:], np.float32)
        y = np.zeros((self.n_pad,), np.int32)
        m = np.zeros((self.n_pad,), np.float32)
        x[:n], y[:n], m[:n] = self.ds.x[idx], self.ds.y[idx], 1.0
        hit = (jnp.asarray(x), jnp.asarray(y), jnp.asarray(m))
        self._pad_cache[cid] = hit
        return hit

    def _device_data(self):
        """One-time device-resident fleet tensor: every client padded to
        ``n_pad`` and stacked (n_clients, n_pad, H, W, C) + labels + row
        masks. After this, batched rounds move only index arrays."""
        if self._fleet_data is None:
            n = len(self.parts)
            xs = np.zeros((n, self.n_pad) + self.ds.x.shape[1:], np.float32)
            ys = np.zeros((n, self.n_pad), np.int32)
            ms = np.zeros((n, self.n_pad), np.float32)
            for cid, idx in enumerate(self.parts):
                k = len(idx)
                xs[cid, :k] = self.ds.x[idx]
                ys[cid, :k] = self.ds.y[idx]
                ms[cid, :k] = 1.0
            self._fleet_data = (jnp.asarray(xs), jnp.asarray(ys),
                                jnp.asarray(ms))
        return self._fleet_data

    def local_update(self, w, cid: int, epochs: int, key):
        x, y, m = self._padded(cid)
        with annotate("local_train"):
            return _local_train(w, x, y, m, key, apply_fn=self.apply_fn,
                                epochs=epochs, batch=self.batch, lr=self.lr,
                                momentum=self.momentum)

    def cluster_round(self, w, participant_ids, n_samples, epochs: int, key):
        if len(participant_ids) == 0:
            return w
        updated = []
        for cid, sub in zip(participant_ids,
                            jax.random.split(key, len(participant_ids))):
            updated.append(self.local_update(w, int(cid), epochs, sub))
        return fedavg(updated, np.asarray(n_samples, np.float64))

    # ---- fleet surface (repro.fl.exec, DESIGN.md §12) ----------------------
    def init_fleet(self):
        """The executor-facing view of the one-time fleet tensor."""
        X, Y, M = self._device_data()
        return {"x": X, "y": Y, "m": M}

    def client_step(self, epochs: int):
        """Pure per-client train fn; memoized per ``epochs`` so the
        executor's jit cache keys on a stable identity."""
        fn = self._step_cache.get(epochs)
        if fn is None:
            # fully unrolling is only worth the compile cost while the
            # total loop count is small (benchmark-scale rounds); the
            # sequential path keeps rolled loops either way
            unroll = epochs * (self.n_pad // self.batch) <= _UNROLL_LIMIT
            fn = partial(_image_client_step, apply_fn=self.apply_fn,
                         epochs=epochs, batch=self.batch, lr=self.lr,
                         momentum=self.momentum, unroll=unroll)
            self._step_cache[epochs] = fn
        return fn

    def fleet_round(self, stacked_w, participant_lists: Sequence[np.ndarray],
                    n_samples: np.ndarray, epochs: int, cluster_keys,
                    pad_to: Optional[int] = None):
        """Pre-executor entry point, kept as a thin delegate: the packing
        and the nested-vmap call now live model-agnostically in
        ``repro.fl.exec.batched.fleet_round``."""
        from repro.fl.exec.batched import fleet_round
        return fleet_round(self, stacked_w, participant_lists, n_samples,
                           epochs, cluster_keys, pad_to=pad_to)

    def stack(self, params_list: list[Any]):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)

    def unstack(self, stacked, k: int):
        return [jax.tree.map(lambda x: x[i], stacked) for i in range(k)]

    def evaluate(self, params) -> dict:
        acc, loss = _evaluate(params, self._xt, self._yt,
                              apply_fn=self.apply_fn)
        return {"acc": float(acc), "loss": float(loss)}

    def model_bits(self, key=None) -> int:
        """Payload bits of one model; cached (sizes are key-independent, and
        the previous per-call re-init dominated engine construction)."""
        if self._model_bits is None:
            p = self.init(key if key is not None else jax.random.PRNGKey(0))
            self._model_bits = int(sum(l.size * 4
                                       for l in jax.tree.leaves(p)) * 8)
        return self._model_bits
