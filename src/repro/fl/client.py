"""FL model adapter: local training executor + cluster-round aggregation.

Implements the ``model`` duck-type consumed by core/session.Session and
fl/baselines.py:

    init(key) -> params
    cluster_round(w, participant_ids, n_samples, epochs, key) -> w'
    local_update(w, client_id, epochs, key) -> w_i  (single client)
    stack(list[params]) / unstack(stacked, K)
    evaluate(params) -> {"acc": ..., "loss": ...}

Local training is one jitted call per (client, round): data is padded to a
fixed ``n_pad`` so every client shares a single compilation; padded rows
are masked out of the loss. SGD-momentum, batch size 10 (paper Table I).
"""
from __future__ import annotations

import inspect
import math
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synth import SynthImageDataset
from repro.fl.models_image import MODEL_ZOO
from repro.optim.optimizers import sgd_init, sgd_update

F32 = jnp.float32


@partial(jax.jit, static_argnames=("apply_fn", "epochs", "batch", "lr",
                                   "momentum"))
def _local_train(params, x, y, mask, key, *, apply_fn, epochs: int,
                 batch: int, lr: float, momentum: float):
    """x: (n_pad, H, W, C); mask: (n_pad,) 1.0 for real rows."""
    n_pad = x.shape[0]
    steps = n_pad // batch

    def loss_fn(p, xb, yb, mb):
        logits = apply_fn(p, xb).astype(F32)
        ce = -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                  yb[:, None], 1)[:, 0]
        return (ce * mb).sum() / jnp.maximum(mb.sum(), 1.0)

    def epoch(carry, ekey):
        p, m = carry
        perm = jax.random.permutation(ekey, n_pad)
        xs = x[perm].reshape(steps, batch, *x.shape[1:])
        ys = y[perm].reshape(steps, batch)
        ms = mask[perm].reshape(steps, batch)

        def step(carry, b):
            p, mstate = carry
            xb, yb, mb = b
            g = jax.grad(loss_fn)(p, xb, yb, mb)
            p, mstate = sgd_update(p, g, mstate, lr=lr, momentum=momentum)
            return (p, mstate), ()

        (p, m), _ = jax.lax.scan(step, (p, m), (xs, ys, ms))
        return (p, m), ()

    m0 = sgd_init(params)
    (params, _), _ = jax.lax.scan(epoch, (params, m0),
                                  jax.random.split(key, epochs))
    return params


@partial(jax.jit, static_argnames=("apply_fn",))
def _evaluate(params, x, y, *, apply_fn):
    logits = apply_fn(params, x).astype(F32)
    pred = logits.argmax(-1)
    ce = -jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], 1)[:, 0]
    return (pred == y).mean(), ce.mean()


def fedavg(params_list: list[Any], weights: np.ndarray):
    w = jnp.asarray(weights / weights.sum(), F32)

    def avg(*leaves):
        stacked = jnp.stack([l.astype(F32) for l in leaves])
        return jnp.einsum("k,k...->...", w, stacked).astype(leaves[0].dtype)

    return jax.tree.map(avg, *params_list)


class ImageFLModel:
    def __init__(self, dataset: SynthImageDataset, partitions: list[np.ndarray],
                 test: SynthImageDataset, model: str = "small-cnn",
                 batch: int = 10, lr: float = 0.02, momentum: float = 0.9,
                 n_pad: Optional[int] = None, **model_kw):
        self.ds, self.parts, self.test = dataset, partitions, test
        self.init_fn, self.apply_fn = MODEL_ZOO[model]
        self.model_kw = dict(in_ch=dataset.x.shape[-1],
                             n_classes=dataset.n_classes, **model_kw)
        if "hw" in inspect.signature(self.init_fn).parameters:
            self.model_kw.setdefault("hw", dataset.x.shape[1])
        self.batch, self.lr, self.momentum = batch, lr, momentum
        sizes = [len(p) for p in partitions]
        self.n_pad = n_pad or batch * math.ceil(max(sizes) / batch)
        self._xt = jnp.asarray(test.x)
        self._yt = jnp.asarray(test.y.astype(np.int32))

    # ---- duck-type ---------------------------------------------------------
    def init(self, key):
        return self.init_fn(key, **self.model_kw)

    def _padded(self, cid: int):
        idx = self.parts[cid]
        n = len(idx)
        x = np.zeros((self.n_pad,) + self.ds.x.shape[1:], np.float32)
        y = np.zeros((self.n_pad,), np.int32)
        m = np.zeros((self.n_pad,), np.float32)
        x[:n], y[:n], m[:n] = self.ds.x[idx], self.ds.y[idx], 1.0
        return jnp.asarray(x), jnp.asarray(y), jnp.asarray(m)

    def local_update(self, w, cid: int, epochs: int, key):
        x, y, m = self._padded(cid)
        return _local_train(w, x, y, m, key, apply_fn=self.apply_fn,
                            epochs=epochs, batch=self.batch, lr=self.lr,
                            momentum=self.momentum)

    def cluster_round(self, w, participant_ids, n_samples, epochs: int, key):
        if len(participant_ids) == 0:
            return w
        updated = []
        for cid, sub in zip(participant_ids,
                            jax.random.split(key, len(participant_ids))):
            updated.append(self.local_update(w, int(cid), epochs, sub))
        return fedavg(updated, np.asarray(n_samples, np.float64))

    def stack(self, params_list: list[Any]):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)

    def unstack(self, stacked, k: int):
        return [jax.tree.map(lambda x: x[i], stacked) for i in range(k)]

    def evaluate(self, params) -> dict:
        acc, loss = _evaluate(params, self._xt, self._yt,
                              apply_fn=self.apply_fn)
        return {"acc": float(acc), "loss": float(loss)}

    def model_bits(self, key=None) -> int:
        p = self.init(key if key is not None else jax.random.PRNGKey(0))
        return int(sum(l.size * 4 for l in jax.tree.leaves(p)) * 8)
