"""BatchedExecutor: model-agnostic device-resident fleet execution
(DESIGN.md §9, §12).

The nested-vmap round — formerly ``ImageFLModel.fleet_round`` /
``fl.client._fleet_round`` — lifted into an executor that works for ANY
adapter exposing the pure fleet surface (``init_fleet`` +
``client_step``): ONE jitted call trains every participant of every
cluster (outer vmap over clusters, inner over padded participants) and
folds the per-cluster sample-weighted FedAvg. Per-participant PRNG keys
are split exactly as the sequential path splits them, so the two
executors differ only by XLA scheduling (ledger bit-equal, weights
tolerance-pinned in tests/test_batched_exec.py).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.exec.base import Executor, has_fleet_surface
from repro.obs.jaxprof import annotate

F32 = jnp.float32


@partial(jax.jit, static_argnames=("step_fn", "spmd_axis"))
def _fleet_train(stacked, data, idx, wt, keys, *, step_fn, spmd_axis=None):
    """Train every participant of every cluster and FedAvg per cluster in
    ONE compiled call.

    stacked: (K, ...) pytree of cluster models; data: fleet pytree with
    leading n_clients dim (``model.init_fleet()``); idx: (K, P)
    participant client ids, dummy-padded; wt: (K, P) sample weights (0.0
    on dummies, which therefore train but never enter the average);
    keys: (K, P, 2) per-participant PRNG keys (the sequential path's
    exact splits); step_fn: the adapter's pure
    ``(params, data_slice, key) -> params`` (static: jit caches on its
    identity, which is why ``client_step`` must memoize); spmd_axis: mesh
    axis name carrying the cluster dim (ShardedExecutor passes "pod" so
    in-step sharding constraints compose with the pod layout).
    """

    def one(p, i, k):
        return step_fn(p, jax.tree.map(lambda a: a[i], data), k)

    # inner vmap: participants share their cluster's model (broadcast);
    # outer vmap: one lane per cluster
    trained = jax.vmap(jax.vmap(one, in_axes=(None, 0, 0)),
                       in_axes=(0, 0, 0),
                       spmd_axis_name=spmd_axis)(stacked, idx, keys)

    wsum = wt.sum(1)                                    # (K,)
    keep = wsum > 0.0                                   # zero-participant
                                                        # clusters keep w_k
    # guard ONLY the zero-participant rows: clamping with max(wsum, 1)
    # would silently down-scale clusters whose weight sum is in (0, 1)
    wn = wt / jnp.where(keep, wsum, 1.0)[:, None]       # (K, P) normalized

    def avg(old, t):
        out = jnp.einsum("kp,kp...->k...", wn, t.astype(F32))
        m = keep.reshape((-1,) + (1,) * (old.ndim - 1))
        return jnp.where(m, out, old.astype(F32)).astype(old.dtype)

    return jax.tree.map(avg, stacked, trained)


def fleet_round(model, stacked_w, participant_lists: Sequence[np.ndarray],
                n_samples: np.ndarray, epochs: int, cluster_keys,
                pad_to: Optional[int] = None, place=None, spmd_axis=None):
    """Batched cluster_round over ALL clusters of any fleet-surface model.

    ``participant_lists[kc]`` holds cluster kc's participant client ids
    this round; ``cluster_keys[kc]`` is the same per-cluster key the
    sequential path hands to ``cluster_round`` (participant keys are
    split from it identically). Clusters are padded to ``pad_to``
    participants (pass the max cluster size for a round-stable compile
    shape); dummies carry weight 0 and drop out of the average.
    ``place`` (ShardedExecutor) may re-place every operand on a mesh
    before the call.
    """
    K = len(participant_lists)
    if K == 0:
        return stacked_w
    P = max([len(p) for p in participant_lists] + [pad_to or 1, 1])
    idx = np.zeros((K, P), np.int32)
    wt = np.zeros((K, P), np.float32)
    keys = np.zeros((K, P, 2), np.uint32)
    ns = np.asarray(n_samples)
    for kc, part in enumerate(participant_lists):
        n = len(part)
        if n == 0:
            continue
        ids = np.asarray(part, np.int64)
        idx[kc, :n] = ids
        wt[kc, :n] = ns[ids]
        keys[kc, :n] = np.asarray(jax.random.split(cluster_keys[kc], n))
    data = model.init_fleet()
    step_fn = model.client_step(epochs)
    operands = (stacked_w, data, jnp.asarray(idx), jnp.asarray(wt),
                jnp.asarray(keys))
    if place is not None:
        operands = place(*operands)
    with annotate("fleet_round"):
        return _fleet_train(*operands, step_fn=step_fn,
                            spmd_axis=spmd_axis)


class BatchedExecutor(Executor):
    name = "batched"

    def __init__(self):
        self._pad = 1
        self._legacy = False

    def prepare(self, cfg, env, model, plan) -> None:
        # pad every round to the max cluster size: one fleet compilation
        # serves the whole session regardless of per-round participation
        self._pad = max((len(c) for c in plan.clusters), default=1)
        # models predating the fleet surface (or wrapping proxies) may
        # only expose the bespoke fleet_round entry point
        self._legacy = (not has_fleet_surface(model)
                        and hasattr(model, "fleet_round"))
        if not self._legacy and not has_fleet_surface(model):
            raise TypeError(
                f"executor {self.name!r} needs a model with the fleet "
                "surface (init_fleet + client_step) or a legacy "
                f"fleet_round; {type(model).__name__} has neither — use "
                "executor='sequential'")

    def train_clusters(self, ctx, plan, state, sels, subs, round_idx):
        cfg, env, model = ctx.cfg, ctx.env, ctx.model
        parts = [sel.participants for sel in sels]
        if self._legacy:
            return model.fleet_round(state.cluster_models, parts,
                                     env.n_samples, cfg.local_epochs, subs,
                                     pad_to=self._pad)
        return fleet_round(model, state.cluster_models, parts,
                           env.n_samples, cfg.local_epochs, subs,
                           pad_to=self._pad, place=self._place(),
                           spmd_axis=self._spmd_axis())

    def _place(self):
        """Operand placement hook; None = leave on the default device."""
        return None

    def _spmd_axis(self):
        """Mesh axis carrying the cluster dim; None = unsharded vmap."""
        return None
