"""Executor protocol: HOW a round's local training executes (DESIGN.md §12).

The RoundEngine decides WHAT trains each round (selection, pacing,
mixing); an ``Executor`` decides HOW — one jitted call per participant
(sequential), one nested-vmap call for the whole fleet (batched), or the
batched call with the stacked fleet tensor sharded cluster-pod-wise
across devices (sharded). The contract:

    prepare(cfg, env, model, plan)
        Once per run(), after the cluster plan is built: validate the
        model surface and derive session-stable shapes (the participant
        pad width).

    train_clusters(ctx, plan, state, sels, subs, round_idx)
        Train every cluster's participants. Returns EITHER a list of
        per-cluster models (sequential) OR a stacked (K, ...) pytree
        (batched/sharded). Must not touch the ledger or either RNG
        stream — that is what keeps the ledger bit-identical across
        executors (pinned in tests/test_batched_exec.py).

    fold(ctx, pacing, state, result, sels, round_idx)
        Route the result into the pacing merge. This is the ONE place
        that knows whether the result is stacked or listed, so pacing
        policies never branch on execution mode: a stacked result goes
        to ``pacing.merge_stacked`` (falling back to unstack +
        ``merge``), a listed result to ``pacing.merge``.

Adapters opt into the batched/sharded executors by exposing the pure
fleet surface (DESIGN.md §12; ImageFLModel and TinyLMFLModel implement
it):

    init_fleet() -> pytree of device arrays, leading dim n_clients
        (all client training data, padded per client, built once)
    client_step(epochs) -> fn(params, data_slice, key) -> params
        (pure jit-stable callable; MUST return the same object for the
        same ``epochs`` so the executor's jit cache keys on identity)

``EngineConfig.executor`` selects by name ("sequential" / "batched" /
"sharded") or passes an instance; the legacy ``batched_exec`` bool maps
through ``resolve_executor`` with a DeprecationWarning.
"""
from __future__ import annotations

import warnings


def has_fleet_surface(model) -> bool:
    """True when ``model`` exposes the pure fleet surface consumed by the
    batched/sharded executors."""
    return hasattr(model, "init_fleet") and hasattr(model, "client_step")


class Executor:
    """Shared fold routing + no-op prepare; subclasses implement
    ``train_clusters``."""

    name = "executor"

    def prepare(self, cfg, env, model, plan) -> None:
        """Per-run() setup after the cluster plan exists."""

    def train_clusters(self, ctx, plan, state, sels, subs, round_idx):
        raise NotImplementedError

    def fold(self, ctx, pacing, state, result, sels, round_idx):
        """Route stacked-vs-listed results into the pacing merge (the
        routing that used to live inline in RoundEngine._train_round)."""
        model = ctx.model
        if isinstance(result, list):
            return pacing.merge(ctx, model, state, result, sels, round_idx)
        if hasattr(pacing, "merge_stacked"):
            return pacing.merge_stacked(ctx, model, state, result, sels,
                                        round_idx)
        return pacing.merge(ctx, model, state,
                            model.unstack(result, len(sels)), sels,
                            round_idx)


def resolve_executor(cfg, model) -> Executor:
    """``EngineConfig.executor`` -> Executor instance.

    Accepts an executor name, an instance, or None. The legacy
    ``cfg.batched_exec`` bool is honored as a deprecation shim with its
    exact old semantics: batched when the model has a fleet path, silent
    sequential fallback otherwise (an EXPLICIT executor="batched" with no
    fleet surface raises instead, in BatchedExecutor.prepare).
    """
    # local import: the implementations import jax-heavy helpers
    from repro.fl.exec.batched import BatchedExecutor
    from repro.fl.exec.sequential import SequentialExecutor
    from repro.fl.exec.sharded import ShardedExecutor

    registry = {"sequential": SequentialExecutor,
                "batched": BatchedExecutor,
                "sharded": ShardedExecutor}
    spec = getattr(cfg, "executor", None)
    if spec is None and getattr(cfg, "batched_exec", False):
        warnings.warn(
            "EngineConfig.batched_exec is deprecated; use "
            "executor='batched' (or 'sharded') instead",
            DeprecationWarning, stacklevel=3)
        fleet_ok = has_fleet_surface(model) or hasattr(model, "fleet_round")
        spec = "batched" if fleet_ok else "sequential"
    if spec is None:
        spec = "sequential"
    if isinstance(spec, str):
        try:
            return registry[spec]()
        except KeyError:
            raise KeyError(f"unknown executor {spec!r}; "
                           f"choose from {sorted(registry)}") from None
    return spec
