"""Pluggable round-execution layer (DESIGN.md §12): HOW local training
runs — sequential (golden bit-parity reference), batched (one
nested-vmap fleet call over any adapter with the pure ``init_fleet`` /
``client_step`` surface), or sharded (the fleet tensor cluster-pod-wise
across devices via ``repro.dist``). Selected by ``EngineConfig.executor``.
"""
from repro.fl.exec.base import (Executor, has_fleet_surface,  # noqa: F401
                                resolve_executor)
from repro.fl.exec.batched import BatchedExecutor, fleet_round  # noqa: F401
from repro.fl.exec.sequential import SequentialExecutor  # noqa: F401
from repro.fl.exec.sharded import ShardedExecutor  # noqa: F401

EXECUTOR_NAMES = ("sequential", "batched", "sharded")
