"""ShardedExecutor: the batched fleet call, cluster-pod-sharded across
devices (DESIGN.md §12).

CroSatFL's cluster = pod mapping (paper §IV, repro.dist): the stacked
(K, ...) cluster models and the (K, P) participant index/weight/key
arrays shard their leading K dim over a 1-axis ("pod",) mesh via
``repro.dist.sharding.param_specs(cluster_dim=True)``; the fleet data
tensor is replicated (every pod holds every client's shard — the
dense-constellation regime has tiny per-satellite data and hundreds of
lanes). The outer cluster vmap carries ``spmd_axis_name="pod"`` and the
call runs under the ``repro.dist.ctx`` rule context, so adapters with
model-side ``shard()`` call sites (the LM adapter) trace their
activation constraints against the same mesh.

Pod width = the largest divisor of K that fits the device count, so the
executor degrades to BatchedExecutor semantics on one device and uses
the whole host mesh under ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` (CI's perf-smoke cell; subprocess-validated in
tests/sharded_check.py). The ledger is host-side accounting and stays
bit-equal to the batched executor's by construction; weights are
tolerance-pinned.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.ctx import use_rules
from repro.dist.sharding import activation_rules, param_specs
from repro.fl.exec.batched import BatchedExecutor


def _pod_size(n_clusters: int, n_devices: int) -> int:
    """Largest divisor of K that the device count can host (the pod axis
    must divide the leading cluster dim or param_specs drops it)."""
    for pod in range(min(max(n_clusters, 1), n_devices), 0, -1):
        if n_clusters % pod == 0:
            return pod
    return 1


class ShardedExecutor(BatchedExecutor):
    name = "sharded"

    def __init__(self):
        super().__init__()
        self.mesh = None
        self._specs = None
        self._data_key = None            # id() of the placed fleet pytree
        self._data_placed = None
        self.last_placement = None       # leaf sharding, for introspection

    def prepare(self, cfg, env, model, plan) -> None:
        super().prepare(cfg, env, model, plan)
        if self._legacy:
            raise TypeError(
                "executor 'sharded' requires the fleet surface (init_fleet "
                f"+ client_step); {type(model).__name__} only has the "
                "legacy fleet_round")
        devs = jax.devices()
        pod = _pod_size(plan.n_clusters, len(devs))
        if self.mesh is None or self.mesh.shape["pod"] != pod:
            self.mesh = Mesh(np.array(devs[:pod]), ("pod",))
            self._specs = None
            self._data_key = self._data_placed = None

    def train_clusters(self, ctx, plan, state, sels, subs, round_idx):
        # activation rules trace against this mesh inside the fleet call;
        # cluster_vmapped: the outer vmap inserts "pod" itself
        rules = activation_rules(self.mesh, cluster_vmapped=True, tp=False)
        with use_rules(self.mesh, rules):
            return super().train_clusters(ctx, plan, state, sels, subs,
                                          round_idx)

    def _spmd_axis(self):
        return "pod"

    def _place(self):
        return self._place_operands

    def _place_operands(self, stacked, data, idx, wt, keys):
        mesh = self.mesh
        if self._specs is None:
            self._specs = param_specs(stacked, mesh, cluster_dim=True,
                                      fsdp=False, tp=False)
        stacked = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            stacked, self._specs)
        leaves = jax.tree.leaves(data)
        if leaves and self._data_key != id(leaves[0]):
            # fleet data is session-constant: replicate it once per mesh
            rep = NamedSharding(mesh, P())
            self._data_placed = jax.tree.map(
                lambda x: jax.device_put(x, rep), data)
            self._data_key = id(leaves[0])
        pod = NamedSharding(mesh, P("pod"))
        idx, wt, keys = (jax.device_put(a, pod) for a in (idx, wt, keys))
        self.last_placement = jax.tree.leaves(stacked)[0].sharding
        return stacked, self._data_placed, idx, wt, keys
