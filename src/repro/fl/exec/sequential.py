"""SequentialExecutor: the golden bit-parity reference (DESIGN.md §12).

The exact per-cluster loop extracted from the pre-executor
``RoundEngine._train_round``: unstack, one jitted ``cluster_round`` per
cluster (one ``_local_train`` dispatch per participant), return the list
for ``PacingPolicy.merge``. Any model implementing the engine duck-type
(``cluster_round``/``stack``/``unstack``) runs here; the golden ledgers
and weights in tests/golden_engine.json are pinned against this path.
"""
from __future__ import annotations

from repro.fl.exec.base import Executor


class SequentialExecutor(Executor):
    name = "sequential"

    def train_clusters(self, ctx, plan, state, sels, subs, round_idx):
        cfg, env, model = ctx.cfg, ctx.env, ctx.model
        models_list = model.unstack(state.cluster_models, len(sels))
        return [
            model.cluster_round(w_k, sel.participants,
                                env.n_samples[sel.participants],
                                cfg.local_epochs, sub)
            for w_k, sel, sub in zip(models_list, sels, subs)]
