"""FL protocol runtime shared by CroSatFL and the baselines."""
from repro.fl.client import ImageFLModel, fedavg  # noqa: F401
from repro.fl.baselines import BASELINES  # noqa: F401
