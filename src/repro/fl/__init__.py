"""FL protocol runtime shared by CroSatFL and the baselines.

The orchestration layer is the pluggable round engine (``repro.fl.engine``,
DESIGN.md §7); ``BASELINES`` and ``core.session.Session`` are preset policy
quadruples over it.
"""
from repro.fl.client import ImageFLModel, fedavg  # noqa: F401
from repro.fl.baselines import BASELINES, BaselineConfig  # noqa: F401
from repro.fl.engine import (EngineConfig, RoundEngine,  # noqa: F401
                             make_baseline, make_crosatfl)
