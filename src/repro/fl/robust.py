"""Byzantine-robust aggregation + quorum gating (DESIGN.md §14).

The transport checksum catches faults the *link* can see — a corrupted
payload is discarded and retransmitted (repro.faults, DESIGN.md §13).
It cannot catch a participant that trained on garbage: radiation-flipped
weight bits, a stuck accelerator, or an adversarial member deliver a
syntactically valid update whose *values* are poison. One such cluster
model entering the cross-aggregation mix contaminates every cluster it
is averaged with (NaNs spread unconditionally; large-norm updates drown
the honest mass). The defense therefore lives at the MERGE, not the
link: the lanes being folded each round are the K delivered fresh
cluster models, and a ``RobustAggregator`` decides what actually commits.

Two orthogonal pieces, both threaded through every ``PacingPolicy``
merge (list and stacked paths) by ``apply_robustness``:

* ``RobustAggregator`` — ``fedavg`` (identity pass-through: each cluster
  keeps its own fresh model, exactly the historical semantics — the
  bit-parity default), coordinate-wise ``median``, ``trimmed_mean``,
  ``norm_clip`` (per-lane delta clipping against the median clean norm;
  the only estimator that preserves lane identity), and ``krum`` /
  multi-Krum (``m > 1``). Non-finite lanes are masked out *before* the
  estimator runs — median/mean would otherwise propagate the very NaNs
  they exist to reject — and each masked lane emits an
  ``obs.robust_reject`` event.
* ``QuorumPolicy`` — gates each cluster's commit on a minimum fraction
  of valid delivered member updates. Below quorum the cluster carries
  its previous model forward (a counted *degraded* round); above it the
  fresh delta is reweighted by the participation fraction, so a cluster
  that lost half its members under skip-many/crash force-skips moves
  half as far (the ROADMAP's quorum-aware merge weights).

Everything here transforms MODEL VALUES only: no ledger field, RNG
stream, or wall-clock is touched, so the mirror-ledger reconcile stays
bit-exact under any aggregator, and with the default
``aggregator="fedavg"``/``quorum=None`` every merge early-outs on a
couple of attribute reads — the golden ledgers stay bit-for-bit.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _lane_finite_mask(stacked, K: int) -> np.ndarray:
    """(K,) bool: lane k is True iff EVERY element of every leaf row k is
    finite. One device sync for the whole pytree."""
    flags = None
    for leaf in jax.tree.leaves(stacked):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        f = jnp.isfinite(leaf).reshape(leaf.shape[0], -1).all(axis=1)
        flags = f if flags is None else flags & f
    if flags is None:
        return np.ones(K, bool)
    return np.asarray(flags)


def _bcast_rows(vec, leaf):
    """(K,) -> (K, 1, ..., 1) broadcastable against a (K, ...) leaf."""
    return jnp.asarray(vec).reshape((-1,) + (1,) * (leaf.ndim - 1))


def _broadcast_lane(stacked, lane, K: int):
    """Replace every row of ``stacked`` with the single model ``lane``."""
    return jax.tree.map(
        lambda s, v: jnp.broadcast_to(v.astype(s.dtype)[None],
                                      s.shape),
        stacked, lane)


class RobustAggregator:
    """Base protocol: ``robustify`` maps the stacked fresh cluster models
    to the stacked models that actually commit.

    ``valid`` is the (K,) bool lane mask computed upstream (False =
    non-finite, already rejected); estimators must consume only valid
    lanes and fall back to ``old_stacked`` when none survive.
    ``identity=True`` marks pass-through aggregators so the engine's
    default path stays pointer-comparison-free.
    """

    name = "robust"
    identity = False

    def robustify(self, old_stacked, new_stacked, valid: np.ndarray,
                  obs=None):
        raise NotImplementedError


class FedAvgAggregator(RobustAggregator):
    """Pass-through: each cluster commits its own fresh model (the
    historical merge semantics, bit-for-bit). Exists so that
    ``EngineConfig.aggregator`` always names a real object."""

    name = "fedavg"
    identity = True

    def robustify(self, old_stacked, new_stacked, valid, obs=None):
        return new_stacked


def _valid_rows(new_stacked, valid: np.ndarray):
    """Gather the valid lanes into a fresh (n_valid, ...) pytree."""
    idx = np.flatnonzero(valid)
    return jax.tree.map(lambda l: l[idx], new_stacked), idx


class MedianAggregator(RobustAggregator):
    """Coordinate-wise median over the valid lanes; every cluster commits
    the consensus (breakdown point f < n/2)."""

    name = "median"

    def robustify(self, old_stacked, new_stacked, valid, obs=None):
        if not valid.any():
            return old_stacked
        rows, _ = _valid_rows(new_stacked, valid)
        med = jax.tree.map(lambda l: jnp.median(l, axis=0), rows)
        return _broadcast_lane(new_stacked, med, len(valid))


class TrimmedMeanAggregator(RobustAggregator):
    """Coordinate-wise trimmed mean: sort the valid lanes per coordinate,
    drop ``floor(trim_frac * n)`` from each end (clamped so at least one
    value survives), and average the rest."""

    name = "trimmed_mean"

    def __init__(self, trim_frac: float = 0.2):
        if not 0.0 <= trim_frac < 0.5:
            raise ValueError(f"trim_frac must be in [0, 0.5), "
                             f"got {trim_frac}")
        self.trim_frac = float(trim_frac)

    def robustify(self, old_stacked, new_stacked, valid, obs=None):
        if not valid.any():
            return old_stacked
        rows, _ = _valid_rows(new_stacked, valid)
        n = int(valid.sum())
        k = min(int(self.trim_frac * n), (n - 1) // 2)

        def tmean(l):
            s = jnp.sort(l, axis=0)
            return jnp.mean(s[k:n - k], axis=0)

        return _broadcast_lane(new_stacked, jax.tree.map(tmean, rows),
                               len(valid))


class NormClipAggregator(RobustAggregator):
    """Per-lane update clipping: each lane's delta (fresh - old) is
    scaled down to at most ``mult`` x the median valid delta norm. The
    only stock estimator that preserves lane identity — honest clusters
    commit their own models untouched; a large-scale corrupted lane is
    tamed instead of discarded. Non-finite lanes revert to their old
    model (a clipped NaN is still a NaN)."""

    name = "norm_clip"

    def __init__(self, mult: float = 2.0):
        if mult <= 0.0:
            raise ValueError(f"mult must be > 0, got {mult}")
        self.mult = float(mult)

    def robustify(self, old_stacked, new_stacked, valid, obs=None):
        K = len(valid)
        sq = None
        for o, nw in zip(jax.tree.leaves(old_stacked),
                         jax.tree.leaves(new_stacked)):
            d = (nw.astype(jnp.float32) - o.astype(jnp.float32))
            contrib = jnp.sum(d.reshape(K, -1) ** 2, axis=1)
            sq = contrib if sq is None else sq + contrib
        norms = np.sqrt(np.asarray(sq, np.float64))
        if not valid.any():
            return old_stacked
        thresh = self.mult * float(np.median(norms[valid]))
        # scale in (0, 1]: 1.0 for lanes within threshold; invalid lanes
        # get scale 0 (commit the old model)
        with np.errstate(divide="ignore", invalid="ignore"):
            scale = np.where(norms > thresh,
                             np.where(norms > 0, thresh / norms, 1.0), 1.0)
        scale = np.where(valid, scale, 0.0)
        if obs is not None:
            for kc in np.flatnonzero(valid & (norms > thresh)):
                obs.robust_reject(int(kc), "norm_clip",
                                  norm=float(norms[kc]),
                                  thresh=float(thresh))
        sc = scale.astype(np.float32)
        return jax.tree.map(
            lambda o, nw: jnp.where(
                _bcast_rows(sc, o) >= 1.0, nw,
                (o + _bcast_rows(sc, o) * (nw - o)).astype(o.dtype)),
            old_stacked, new_stacked)


class KrumAggregator(RobustAggregator):
    """(multi-)Krum over the valid lanes: score each lane by the sum of
    its ``n - f - 2`` smallest squared distances to the other lanes and
    commit the mean of the ``m`` best-scored lanes. With fewer than 3
    valid lanes the scores are degenerate; fall back to the mean of all
    valid lanes."""

    name = "krum"

    def __init__(self, f: int = 1, m: int = 1):
        if f < 0 or m < 1:
            raise ValueError(f"need f >= 0 and m >= 1, got f={f} m={m}")
        self.f, self.m = int(f), int(m)

    def robustify(self, old_stacked, new_stacked, valid, obs=None):
        if not valid.any():
            return old_stacked
        rows, idx = _valid_rows(new_stacked, valid)
        n = len(idx)
        flat = jnp.concatenate(
            [l.reshape(n, -1).astype(jnp.float32)
             for l in jax.tree.leaves(rows)], axis=1)
        if n < 3:
            sel = np.arange(n)
        else:
            d2 = np.asarray(jnp.sum(
                (flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1),
                np.float64)
            np.fill_diagonal(d2, np.inf)
            closest = max(1, n - self.f - 2)
            scores = np.sort(d2, axis=1)[:, :closest].sum(axis=1)
            sel = np.argsort(scores, kind="stable")[:min(self.m, n)]
        if obs is not None:
            for j in range(n):
                if j not in sel:
                    obs.robust_reject(int(idx[j]), "krum")
        chosen = jax.tree.map(lambda l: jnp.mean(l[np.sort(sel)], axis=0),
                              rows)
        return _broadcast_lane(new_stacked, chosen, len(valid))


AGGREGATORS = {
    "fedavg": FedAvgAggregator,
    "median": MedianAggregator,
    "trimmed_mean": TrimmedMeanAggregator,
    "norm_clip": NormClipAggregator,
    "krum": KrumAggregator,
}


def resolve_aggregator(spec) -> RobustAggregator:
    """``EngineConfig.aggregator`` -> aggregator instance: a registry
    name, an instance, or None (-> fedavg pass-through)."""
    if spec is None:
        return FedAvgAggregator()
    if isinstance(spec, RobustAggregator):
        return spec
    if isinstance(spec, str):
        try:
            return AGGREGATORS[spec]()
        except KeyError:
            raise KeyError(f"unknown aggregator {spec!r}; "
                           f"choose from {sorted(AGGREGATORS)}") from None
    raise TypeError("aggregator must be a name, RobustAggregator "
                    f"instance, or None, got {type(spec).__name__}")


class QuorumPolicy:
    """Commit gate on the fraction of valid delivered member updates.

    ``fraction`` for a cluster = trained / engaged from its
    ``RoundSelection`` (1.0 for empty clusters — nothing was owed).
    ``degraded`` counts below-quorum carry-forward rounds across the
    session (surfaced in reports and the chaos harness).
    """

    def __init__(self, min_frac: float = 0.5):
        if not 0.0 < min_frac <= 1.0:
            raise ValueError(f"min_frac must be in (0, 1], got {min_frac}")
        self.min_frac = float(min_frac)
        self.degraded = 0

    def fractions(self, sels) -> np.ndarray:
        out = np.empty(len(sels))
        for kc, sel in enumerate(sels):
            engaged = len(sel.ids)
            out[kc] = (float(sel.mask.sum()) / engaged if engaged
                       else 1.0)
        return out


def resolve_quorum(spec) -> Optional[QuorumPolicy]:
    """``EngineConfig.quorum`` -> None | QuorumPolicy (a float is the
    minimum fraction)."""
    if spec is None or isinstance(spec, QuorumPolicy):
        return spec
    if isinstance(spec, (int, float)) and not isinstance(spec, bool):
        return QuorumPolicy(float(spec))
    raise TypeError("quorum must be a min-fraction float, QuorumPolicy, "
                    f"or None, got {type(spec).__name__}")


def apply_robustness(ctx, model, state, fresh, sels):
    """Run the configured aggregator + quorum gate over this round's
    fresh cluster models, called at the TOP of every pacing merge.

    ``fresh`` may be the list the sequential executor produced or the
    stacked (K, ...) pytree of the batched/sharded paths; the same
    container type comes back so merge code downstream is unchanged.
    With the default fedavg aggregator and no quorum this is a
    pass-through after two attribute reads (golden bit-parity).
    """
    robust = getattr(ctx, "robust", None)
    quorum = getattr(ctx, "quorum", None)
    if (robust is None or robust.identity) and quorum is None:
        return fresh
    K = len(sels)
    is_list = isinstance(fresh, list)
    stacked = model.stack(fresh) if is_list else fresh
    old = state.cluster_models
    obs = getattr(ctx, "obs", None)

    if robust is not None and not robust.identity:
        valid = _lane_finite_mask(stacked, K)
        if obs is not None:
            for kc in np.flatnonzero(~valid):
                obs.robust_reject(int(kc), "nonfinite")
        stacked = robust.robustify(old, stacked, valid, obs=obs)

    if quorum is not None:
        fracs = quorum.fractions(sels)
        ok = fracs >= quorum.min_frac
        quorum.degraded += int((~ok).sum())
        if obs is not None:
            for kc in range(K):
                obs.quorum(kc, float(fracs[kc]), bool(ok[kc]))
        # below quorum: carry the old model forward (degraded round);
        # above: move by the participation fraction — a cluster that
        # delivered 70% of its members commits 70% of its delta. Full
        # quorum keeps the fresh model VERBATIM (no float detour).
        coeff = np.where(ok, fracs, 0.0).astype(np.float32)
        stacked = jax.tree.map(
            lambda o, nw: jnp.where(
                _bcast_rows(coeff, o) >= 1.0, nw,
                (o + _bcast_rows(coeff, o)
                 * (nw - o)).astype(o.dtype)),
            old, stacked)

    return model.unstack(stacked, K) if is_list else stacked
