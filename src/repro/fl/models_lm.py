"""TinyLMFLModel: a repro.models transformer behind the FL model
duck-type AND the pure fleet surface (DESIGN.md §12).

Proves the executor layer is model-agnostic: the same engine/session
code that drives ``ImageFLModel`` drives a (reduced) ``stablelm-3b``
language model through the sequential, batched, and sharded executors —
``benchmarks.run --smoke`` exercises the batched cell.

Task: synthetic cyclic-arithmetic next-token prediction. Client ``c``'s
sequences step through the vocab with stride ``1 + (c % 7)`` —
``tokens[t] = (s0 + t * stride) % V`` — so the data is non-IID across
clients (each shard teaches a different stride) while being learnable by
a tiny model and wrapping cleanly at any position. Labels are the
shifted-by-one tokens; held-out evaluation predicts the last position
via ``lm_prefill``.

Local training is full-batch SGD-momentum over the client's padded
shard: ``lm_loss``'s ``batch["weights"]`` zero-weights pad rows and the
loss mean renormalizes, so padded and unpadded shards optimize the same
objective. The per-client step is one epochs-long ``lax.scan`` — pure
``(params, data_slice, key) -> params`` (the key is accepted for surface
parity and unused: full-batch GD draws nothing), memoized per ``epochs``
so the executors' jit caches key on a stable identity.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.fl.client import fedavg
from repro.models.transformer import lm_loss, lm_params, lm_prefill
from repro.optim.optimizers import sgd_init, sgd_update


def _lm_client_step(params, data, key, *, cfg, epochs: int, lr: float,
                    momentum: float):
    """One client's padded shard through ``epochs`` full-batch SGD steps."""
    del key  # surface parity: full-batch GD is deterministic
    batch = {"tokens": data["tokens"], "labels": data["labels"],
             "weights": data["w"]}

    def step(carry, _):
        p, m = carry
        g = jax.grad(lambda q: lm_loss(q, batch, cfg, remat=False))(p)
        p, m = sgd_update(p, g, m, lr=lr, momentum=momentum)
        return (p, m), ()

    (params, _), _ = jax.lax.scan(step, (params, sgd_init(params)), None,
                                  length=epochs)
    return params


class TinyLMFLModel:
    """Reduced-transformer FL adapter over synthetic stride sequences.

    Implements the engine model duck-type (init / cluster_round /
    local_update / stack / unstack / evaluate / model_bits) plus the
    fleet surface (init_fleet / client_step) so every executor accepts
    it. Float32 end-to-end: CPU FL parity runs drown in bf16 noise.
    """

    def __init__(self, n_clients: int, n_per_client: int = 8, seq: int = 16,
                 arch: str = "stablelm-3b", lr: float = 0.05,
                 momentum: float = 0.9, seed: int = 0,
                 sizes: Optional[Sequence[int]] = None, n_test: int = 32):
        self.cfg = get_config(arch).reduced(dtype=jnp.float32,
                                            max_positions=max(seq, 8))
        self.n_clients, self.n_pad, self.seq = n_clients, n_per_client, seq
        self.lr, self.momentum = lr, momentum
        rng = np.random.default_rng(seed)
        V = self.cfg.vocab_size
        sizes = list(sizes) if sizes is not None \
            else [n_per_client] * n_clients
        if len(sizes) != n_clients or max(sizes) > n_per_client:
            raise ValueError("sizes must give <= n_per_client per client")
        self.sizes = np.asarray(sizes, np.int64)

        def gen(n, stride):
            s0 = rng.integers(0, V, size=(n, 1))
            t = np.arange(seq + 1)[None, :]
            path = (s0 + t * stride) % V
            return path[:, :-1].astype(np.int32), path[:, 1:].astype(np.int32)

        toks = np.zeros((n_clients, n_per_client, seq), np.int32)
        labs = np.zeros((n_clients, n_per_client, seq), np.int32)
        wts = np.zeros((n_clients, n_per_client), np.float32)
        for c in range(n_clients):
            n = int(self.sizes[c])
            toks[c, :n], labs[c, :n] = gen(n, 1 + c % 7)
            wts[c, :n] = 1.0
        self._fleet = {"tokens": jnp.asarray(toks),
                       "labels": jnp.asarray(labs),
                       "w": jnp.asarray(wts)}
        # held-out: every stride clients train on, fresh start tokens
        tt, tl = zip(*(gen(max(n_test // max(n_clients, 1), 1), 1 + c % 7)
                       for c in range(n_clients)))
        self._test = {"tokens": jnp.asarray(np.concatenate(tt)),
                      "labels": jnp.asarray(np.concatenate(tl))}
        self._step_cache: dict[int, Any] = {}   # epochs -> client_step fn
        self._jit_cache: dict[int, Any] = {}    # epochs -> jitted step fn
        self._model_bits: Optional[int] = None

    # ---- duck-type ---------------------------------------------------------
    def init(self, key):
        return lm_params(self.cfg, key)

    def local_update(self, w, cid: int, epochs: int, key):
        fn = self._jit_cache.get(epochs)
        if fn is None:
            fn = jax.jit(self.client_step(epochs))
            self._jit_cache[epochs] = fn
        data = jax.tree.map(lambda a: a[cid], self._fleet)
        return fn(w, data, key)

    def cluster_round(self, w, participant_ids, n_samples, epochs: int, key):
        if len(participant_ids) == 0:
            return w
        updated = []
        for cid, sub in zip(participant_ids,
                            jax.random.split(key, len(participant_ids))):
            updated.append(self.local_update(w, int(cid), epochs, sub))
        return fedavg(updated, np.asarray(n_samples, np.float64))

    # ---- fleet surface (repro.fl.exec, DESIGN.md §12) ----------------------
    def init_fleet(self):
        return self._fleet

    def client_step(self, epochs: int):
        fn = self._step_cache.get(epochs)
        if fn is None:
            fn = partial(_lm_client_step, cfg=self.cfg, epochs=epochs,
                         lr=self.lr, momentum=self.momentum)
            self._step_cache[epochs] = fn
        return fn

    def stack(self, params_list: list[Any]):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)

    def unstack(self, stacked, k: int):
        return [jax.tree.map(lambda x: x[i], stacked) for i in range(k)]

    def evaluate(self, params) -> dict:
        logits = lm_prefill(params, self._test, self.cfg)
        acc = (logits.argmax(-1) == self._test["labels"][:, -1]).mean()
        loss = lm_loss(params, self._test, self.cfg, remat=False)
        return {"acc": float(acc), "loss": float(loss)}

    def model_bits(self, key=None) -> int:
        if self._model_bits is None:
            p = self.init(key if key is not None else jax.random.PRNGKey(0))
            self._model_bits = int(sum(l.size * 4
                                       for l in jax.tree.leaves(p)) * 8)
        return self._model_bits
