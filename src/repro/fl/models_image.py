"""Image classifiers for the FL simulation (pure JAX).

``SmallCNN`` is the default client model for CPU-speed simulation runs;
``ResNet18`` is the paper's model (width-scalable so tests stay fast).
Both are functional: ``init(key, ...) -> params``, ``apply(params, x) ->
logits`` with x (B, H, W, C) float32.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def _conv(x, w, stride: int = 1):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _he(key, *shape):
    fan_in = math.prod(shape[:-1])
    return jax.random.normal(key, shape, F32) * math.sqrt(2.0 / fan_in)


# ---------------------------------------------------------------------------
# SmallCNN
# ---------------------------------------------------------------------------

def cnn_init(key, in_ch: int = 3, n_classes: int = 10, width: int = 16,
             hw: int = 16):
    ks = iter(jax.random.split(key, 8))
    return {
        "c1": _he(next(ks), 3, 3, in_ch, width),
        "b1": jnp.zeros(width),
        "c2": _he(next(ks), 3, 3, width, 2 * width),
        "b2": jnp.zeros(2 * width),
        "c3": _he(next(ks), 3, 3, 2 * width, 2 * width),
        "b3": jnp.zeros(2 * width),
        "w": _he(next(ks), 2 * width, n_classes),
        "b": jnp.zeros(n_classes),
        # zero-init linear shortcut (matched-filter head): the global
        # average pool discards spatial phase, so the conv path alone needs
        # many epochs before templates become separable — far more than an
        # edge round budget. The shortcut lets the pixel-level matched
        # filter emerge within the first rounds without perturbing the
        # conv path at init.
        "lw": jnp.zeros((hw * hw * in_ch, n_classes)),
    }


def cnn_apply(params, x):
    h = jax.nn.relu(_conv(x, params["c1"]) + params["b1"])
    h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                          "VALID")
    h = jax.nn.relu(_conv(h, params["c2"]) + params["b2"])
    h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                          "VALID")
    h = jax.nn.relu(_conv(h, params["c3"]) + params["b3"])
    h = h.mean((1, 2))                       # global average pool
    logits = h @ params["w"] + params["b"]
    return logits + x.reshape(x.shape[0], -1) @ params["lw"]


# ---------------------------------------------------------------------------
# ResNet-18 (width-scalable; GroupNorm so FL averaging is sound — BN running
# stats are notoriously ill-defined under FedAvg)
# ---------------------------------------------------------------------------

def _gn_init(ch):
    return {"scale": jnp.ones(ch), "bias": jnp.zeros(ch)}


def _gn(p, x, groups: int = 8):
    B, H, W, C = x.shape
    g = math.gcd(groups, C)
    xg = x.reshape(B, H, W, g, C // g).astype(F32)
    mu = xg.mean((1, 2, 4), keepdims=True)
    var = xg.var((1, 2, 4), keepdims=True)
    xn = ((xg - mu) * lax.rsqrt(var + 1e-5)).reshape(B, H, W, C)
    return (xn * p["scale"] + p["bias"]).astype(x.dtype)


def _block_init(key, cin, cout, stride):
    ks = iter(jax.random.split(key, 4))
    p = {
        "c1": _he(next(ks), 3, 3, cin, cout), "n1": _gn_init(cout),
        "c2": _he(next(ks), 3, 3, cout, cout), "n2": _gn_init(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _he(next(ks), 1, 1, cin, cout)
    return p


def _block_apply(p, x, stride):
    h = jax.nn.relu(_gn(p["n1"], _conv(x, p["c1"], stride)))
    h = _gn(p["n2"], _conv(h, p["c2"]))
    sc = _conv(x, p["proj"], stride) if "proj" in p else x
    return jax.nn.relu(h + sc)


STAGES = ((2, 1), (2, 2), (2, 2), (2, 2))   # (blocks, first-stride) x 4


def resnet18_init(key, in_ch: int = 3, n_classes: int = 10, width: int = 64):
    ks = iter(jax.random.split(key, 32))
    p: dict[str, Any] = {"stem": _he(next(ks), 3, 3, in_ch, width),
                         "stem_n": _gn_init(width)}
    cin = width
    for s, (blocks, stride) in enumerate(STAGES):
        cout = width * (2 ** s)
        for b in range(blocks):
            p[f"s{s}b{b}"] = _block_init(next(ks), cin, cout,
                                         stride if b == 0 else 1)
            cin = cout
    p["head_w"] = _he(next(ks), cin, n_classes)
    p["head_b"] = jnp.zeros(n_classes)
    return p


def resnet18_apply(params, x):
    h = jax.nn.relu(_gn(params["stem_n"], _conv(x, params["stem"])))
    for s, (blocks, stride) in enumerate(STAGES):
        for b in range(blocks):
            h = _block_apply(params[f"s{s}b{b}"], h, stride if b == 0 else 1)
    h = h.mean((1, 2))
    return h @ params["head_w"] + params["head_b"]


MODEL_ZOO = {
    "small-cnn": (cnn_init, cnn_apply),
    "resnet18": (resnet18_init, resnet18_apply),
}
