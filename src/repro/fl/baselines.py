"""The five comparison baselines (paper §V-A), each with its native
communication + synchronization pattern and full energy/latency accounting
on the same constellation env and model adapter as CroSatFL.

  FedSyn   — synchronous FedAvg, GS-centric: every round every client
             uploads to the GS and receives the new global model.
  FedLEO   — intra-plane propagation + sink-satellite scheduling: clients
             grouped by orbital plane; updates propagate along the plane
             chain to a sink; sinks talk to the GS.
  FELLO    — optical-LISL clustering + edge selection: greedy clusters,
             members upload to cluster heads, heads chain to one elected
             head which is the only GS contact per round.
  FedSCS   — energy-aware client selection, GS-centric: top-m clients by
             an energy utility participate each round.
  FedOrbit — FedSCS-style orbital FL with block-minifloat arithmetic:
             reduced-precision payload (x bits/32) and reduced compute
             energy (arith_scale).

Baselines are NOT constrained to CroSatFL's once-per-session GS pattern
(paper §V-A).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import (EnergyLedger, e_gs, e_lisl, e_train, t_gs,
                               t_lisl, t_train)
from repro.fl.client import fedavg


@dataclass(frozen=True)
class BaselineConfig:
    rounds: int = 40
    local_epochs: int = 10
    c_flop: float = 5e7
    model_bits: float = 8 * 44.7e6
    seed: int = 0
    # FedSCS / FedOrbit
    select_m: int = 16
    # FedOrbit block-minifloat
    minifloat_bits: int = 12           # of 32
    arith_scale: float = 0.5           # compute-energy reduction factor


def _profiles_arrays(env):
    alpha = np.array([p.alpha for p in env.profiles])
    return alpha


class _Engine:
    """Shared round loop; subclasses define selection + communication."""

    name = "base"

    def __init__(self, cfg: BaselineConfig, env, model):
        self.cfg, self.env, self.model = cfg, env, model
        self.rng = np.random.default_rng(cfg.seed)
        alpha = _profiles_arrays(env)
        self.tt = t_train(env.n_samples, cfg.c_flop, alpha, cfg.local_epochs)
        self.et = e_train(env.n_samples, cfg.c_flop, env.profiles,
                          cfg.local_epochs)

    # hooks ------------------------------------------------------------------
    def select(self, r: int) -> np.ndarray:
        return np.arange(self.env.n_clients)

    def communicate(self, participants: np.ndarray, ledger: EnergyLedger,
                    t_now: float):
        """Account one round of update collection + redistribution."""
        raise NotImplementedError

    def payload_bits(self) -> float:
        return self.cfg.model_bits

    def compute_energy(self, participants: np.ndarray) -> float:
        return float(self.et[participants].sum())

    # round loop ---------------------------------------------------------------
    def run(self, eval_fn: Optional[Callable] = None):
        cfg, env = self.cfg, self.env
        key = jax.random.PRNGKey(cfg.seed)
        ledger = EnergyLedger()
        key, sub = jax.random.split(key)
        w = self.model.init(sub)
        history = []
        wall = 0.0
        for r in range(cfg.rounds):
            part = self.select(r)
            jitter = self.rng.lognormal(0.0, 0.25, len(part))
            tt_r = self.tt[part] * jitter
            key, sub = jax.random.split(key)
            w = self.model.cluster_round(w, part, env.n_samples[part],
                                         cfg.local_epochs, sub)
            barrier = float(tt_r.max())
            ledger.add_train(self.compute_energy(part) * self._arith_scale(),
                             barrier)
            ledger.add_wait(float((barrier - tt_r).sum()))
            wall += barrier
            wall += self.communicate(part, ledger, wall)
            ledger.wall_clock_s = wall
            if eval_fn is not None:
                m = eval_fn(w, r)
                m["round"] = r
                m.update(ledger.row())
                history.append(m)
        return w, ledger, history

    def _arith_scale(self) -> float:
        return 1.0


class FedSyn(_Engine):
    name = "FedSyn"

    def communicate(self, part, ledger, t_now):
        env, d = self.env, self.payload_bits()
        lp = env.link_params
        waits = []
        for i in part:
            wait, dist = env.gs_window_wait(int(i), t_now)
            waits.append(wait)
            # upload + download
            ledger.add_gs(2, 2 * e_gs(d, lp.gs_rate, dist, lp),
                          2 * t_gs(d, lp.gs_rate, dist, lp))
        # synchronous: the round ends when the LAST client has synced;
        # everyone else idles (latency-only waiting)
        wmax = max(waits)
        ledger.add_wait(float(np.sum(wmax - np.asarray(waits))))
        return wmax


class FedLEO(_Engine):
    name = "FedLEO"

    def __init__(self, cfg, env, model):
        super().__init__(cfg, env, model)
        planes = env.constellation.plane_of(env.sat_ids)
        self.groups = [np.flatnonzero(planes == p) for p in np.unique(planes)]
        # merge singleton planes into neighbors to form propagation chains
        merged, cur = [], []
        for g in self.groups:
            cur = np.concatenate([cur, g]).astype(int) if len(cur) else g
            if len(cur) >= 3:
                merged.append(cur)
                cur = []
        if len(cur):
            merged.append(cur)
        self.groups = merged

    def communicate(self, part, ledger, t_now):
        env, d = self.env, self.payload_bits()
        lp = env.link_params
        waits = []
        for g in self.groups:
            sink = int(g[np.argmax(env.fanout[g])])
            # chain propagation to sink and back: 2 LISL msgs per non-sink
            for i in g:
                if int(i) == sink:
                    continue
                dist = env.lisl_distance(int(i), sink, t_now)
                dist = dist if np.isfinite(dist) else 3e6
                ledger.add_intra(2, 2 * e_lisl(d, lp.lisl_rate, dist, lp),
                                 2 * t_lisl(d, lp.lisl_rate, dist, lp))
            wait, gdist = env.gs_window_wait(sink, t_now)
            waits.append(wait)
            ledger.add_gs(2, 2 * e_gs(d, lp.gs_rate, gdist, lp),
                          2 * t_gs(d, lp.gs_rate, gdist, lp))
        wmax = max(waits)
        ledger.add_wait(float(np.sum(wmax - np.asarray(waits))))
        return wmax


class FELLO(_Engine):
    name = "FELLO"

    def __init__(self, cfg, env, model, n_clusters: int = 9):
        super().__init__(cfg, env, model)
        # greedy geographic clustering (optical-LISL feasible neighborhoods)
        n_clusters = max(1, min(n_clusters, env.n_clients // 2))
        order = np.argsort(-env.fanout)
        self.clusters = [order[i::n_clusters] for i in range(n_clusters)]
        self.heads = [int(c[np.argmax(env.fanout[c])]) for c in self.clusters]

    def communicate(self, part, ledger, t_now):
        env, d = self.env, self.payload_bits()
        lp = env.link_params
        # members <-> heads
        for c, h in zip(self.clusters, self.heads):
            for i in c:
                if int(i) == h:
                    continue
                dist = env.lisl_distance(int(i), h, t_now)
                dist = dist if np.isfinite(dist) else 3e6
                ledger.add_intra(2, 2 * e_lisl(d, lp.lisl_rate, dist, lp),
                                 2 * t_lisl(d, lp.lisl_rate, dist, lp))
        # heads chain to elected head
        elect = self.heads[0]
        for h in self.heads[1:]:
            dist = env.lisl_distance(h, elect, t_now)
            dist = dist if np.isfinite(dist) else 3e6
            ledger.add_intra(2, 2 * e_lisl(d, lp.lisl_rate, dist, lp),
                             2 * t_lisl(d, lp.lisl_rate, dist, lp))
        wait, gdist = env.gs_window_wait(elect, t_now)
        ledger.add_gs(2, 2 * e_gs(d, lp.gs_rate, gdist, lp),
                      2 * t_gs(d, lp.gs_rate, gdist, lp))
        return wait


class FedSCS(_Engine):
    name = "FedSCS"

    def select(self, r):
        # energy-aware: prefer low-energy, fast clients; rotate by round for
        # coverage (the original uses a knapsack-style utility)
        util = -self.et / self.et.max() - 0.5 * self.tt / self.tt.max()
        noise = self.rng.normal(0, 0.1, len(util))
        return np.argsort(-(util + noise))[: self.cfg.select_m]

    def communicate(self, part, ledger, t_now):
        env, d = self.env, self.payload_bits()
        lp = env.link_params
        waits = []
        for i in part:
            # relay to a GS-visible satellite over 2 LISL hops (up + down)
            dist = 1.2e6
            ledger.add_intra(4, 4 * e_lisl(d, lp.lisl_rate, dist, lp),
                             4 * t_lisl(d, lp.lisl_rate, dist, lp))
            wait, gdist = env.gs_window_wait(int(i), t_now)
            waits.append(wait)
            ledger.add_gs(2, 2 * e_gs(d, lp.gs_rate, gdist, lp),
                          2 * t_gs(d, lp.gs_rate, gdist, lp))
        wmax = max(waits)
        ledger.add_wait(float(np.sum(wmax - np.asarray(waits))))
        return wmax


class FedOrbit(FedSCS):
    name = "FedOrbit"

    def payload_bits(self):
        return self.cfg.model_bits * self.cfg.minifloat_bits / 32.0

    def _arith_scale(self):
        return self.cfg.arith_scale


BASELINES = {b.name: b for b in (FedSyn, FedLEO, FELLO, FedSCS, FedOrbit)}
