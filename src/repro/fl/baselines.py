"""The five comparison baselines (paper §V-A) on the shared round engine.

Each baseline is a policy quadruple over ``repro.fl.engine.RoundEngine``
(see fl/engine/presets.py) — the bespoke per-baseline loops are gone, so
all six algorithms share one implementation of the round skeleton and one
accounting rule (the point of Table II):

  FedSyn   — synchronous FedAvg, GS-centric: every round every client
             uploads to the GS and receives the new global model.
  FedLEO   — intra-plane propagation + sink-satellite scheduling: clients
             grouped by orbital plane; updates propagate along the plane
             chain to a sink; sinks talk to the GS.
  FELLO    — optical-LISL clustering + edge selection: greedy clusters,
             members upload to cluster heads, heads chain to one elected
             head which is the only GS contact per round.
  FedSCS   — energy-aware client selection, GS-centric: top-m clients by
             an energy utility participate each round.
  FedOrbit — FedSCS with a block-minifloat payload codec: reduced-precision
             payload (bits/32) and reduced compute energy (arith_scale).

Baselines are NOT constrained to CroSatFL's once-per-session GS pattern
(paper §V-A). ``BASELINES[name](cfg, env, model)`` returns a ready
``RoundEngine`` (``.run(eval_fn=...)`` as before); golden parity with the
pre-refactor loops is pinned by tests/test_engine_parity.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.fl.engine import BASELINE_NAMES, EngineConfig, make_baseline


@dataclass(frozen=True)
class BaselineConfig:
    rounds: int = 40
    local_epochs: int = 10
    c_flop: Any = 5e7                # or "measured:<arch>/<shape>"
    model_bits: float = 8 * 44.7e6
    seed: int = 0
    # FedSCS / FedOrbit
    select_m: int = 16
    # FedOrbit block-minifloat
    minifloat_bits: int = 12           # of 32
    arith_scale: float = 0.5           # compute-energy reduction factor

    def engine_config(self) -> EngineConfig:
        return EngineConfig(rounds=self.rounds,
                            local_epochs=self.local_epochs,
                            c_flop=self.c_flop, model_bits=self.model_bits,
                            seed=self.seed)


def build_baseline(name: str, cfg: BaselineConfig, env, model, **kw):
    """Build (NOT run) the named baseline engine (``**kw``: e.g. FELLO
    n_clusters); call ``.run(eval_fn=...)`` on the result."""
    return make_baseline(name, cfg.engine_config(), env, model,
                         select_m=cfg.select_m,
                         minifloat_bits=cfg.minifloat_bits,
                         arith_scale=cfg.arith_scale, **kw)


class _BaselineFactory:
    """Keeps the legacy ``BASELINES[name](cfg, env, model)`` call shape."""

    def __init__(self, name: str):
        self.name = name

    def __call__(self, cfg: BaselineConfig, env, model, **kw):
        return build_baseline(self.name, cfg, env, model, **kw)


BASELINES = {name: _BaselineFactory(name) for name in BASELINE_NAMES}
