"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304.

Alternating sLSTM / mLSTM blocks (6 periods of 2). No separate FFN (d_ff=0):
blocks carry their own up/down projections. Constant-size recurrent state
=> long_500k runs trivially. [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    prefer_tp=False,
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope_variant="none",
    pattern=(("slstm", "none"), ("mlstm", "none")),
    num_periods=6,
    xlstm_proj_factor=2.0,
    act="gelu",
    supports_long_context=True,
)
