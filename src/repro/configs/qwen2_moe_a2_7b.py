"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408(expert)
vocab=151936, MoE 60 routed top-4 + 4 shared (each 1408).

Routed experts padded 60->64 for clean EP over the 16-way model axis.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    prefer_tp=False,
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    num_experts=64,   # 60 routed padded to 64 for clean 16-way EP
    num_shared_experts=4,
    moe_top_k=4,
    moe_groups=16,    # group-local dispatch (§Perf)
    moe_d_ff=1408,
    pattern=(("attn", "moe"),),
    act="silu",
    mlp_gated=True,
    supports_long_context=False,
)
