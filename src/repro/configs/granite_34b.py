"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

Code model, GPTBigCode-style: MQA (single kv head), non-gated GeLU MLP
(2-matrix FFN keeps the listed config at ~34B params). [arXiv:2405.04324; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    supports_long_context=False,
    notes="MQA kv=1: kv proj replicated under TP; q heads sharded",
)
