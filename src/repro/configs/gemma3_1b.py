"""gemma3-1b [dense] — 26L d_model=1152 4H (MQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global attention interleave (local = 512-token sliding window),
head_dim=256, qk-norm, sandwich norms, gated GeLU. 128k+ context capable;
SWA keeps long_500k sub-quadratic. [hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchConfig

_PERIOD = (("attn_local", "mlp"),) * 5 + (("attn_global", "mlp"),)

CONFIG = ArchConfig(
    prefer_tp=False,
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    sliding_window=512,
    qk_norm=True,
    sandwich_norm=True,
    pattern=_PERIOD,
    num_periods=4,
    suffix_pattern=(("attn_local", "mlp"), ("attn_local", "mlp")),
    act="gelu",
    mlp_gated=True,
    tie_embeddings=True,
    supports_long_context=True,
    notes="local layers SWA(512); 4 global layers carry the 500k cache (kv=1)",
)
