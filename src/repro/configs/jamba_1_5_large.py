"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2.

Mamba:attention 7:1 interleave (attention mid-block), MoE every other layer.
9 periods x 8 layers. Mamba state is O(1) in sequence; the 9 attention
layers' 500k cache is head_dim-sharded. [arXiv:2403.19887; hf]
"""
from repro.configs.base import ArchConfig

_PERIOD = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
    ("attn",  "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    rope_variant="none",        # jamba uses no positional encoding in attn
    num_experts=16,
    num_shared_experts=0,
    moe_top_k=2,
    moe_groups=16,    # group-local dispatch (single-pod; §Perf)
    moe_d_ff=24576,
    pattern=_PERIOD,
    num_periods=9,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    act="silu",
    mlp_gated=True,
    supports_long_context=True,
    notes="1:7 attn:mamba; 398B total / ~94B active",
)
