"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.

StableLM family: partial rotary (25%), LayerNorm, gated SiLU MLP.
[hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    prefer_tp=False,
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    rope_pct=0.25,
    norm="layernorm",
    act="silu",
    mlp_gated=True,
    supports_long_context=False,
)
