"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (3-section rotary over t/h/w), dynamic resolution handled by the
(stubbed) vision frontend: ``input_specs()`` supplies precomputed patch
embeddings spliced at the sequence head. [arXiv:2409.12191; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    prefer_tp=False,
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    rope_variant="mrope",
    rope_theta=1_000_000.0,
    frontend="patches",
    num_patches=256,
    act="silu",
    mlp_gated=True,
    supports_long_context=False,
    notes="M-RoPE sections (16,24,24) over head_dim/2; patch embeds stubbed",
)
