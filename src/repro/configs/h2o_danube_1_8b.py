"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.

Llama+Mistral mix with sliding-window attention (4096) on all layers —
cache is window-bounded, so long_500k runs. [arXiv:2401.16818; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    prefer_tp=False,
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    pattern=(("attn_local", "mlp"),),
    act="silu",
    mlp_gated=True,
    supports_long_context=True,
    notes="SWA(4096) everywhere: decode cache bounded by the window",
)
