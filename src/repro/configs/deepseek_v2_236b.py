"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400, MoE 160 routed top-6 + 2 shared, MLA kv_lora=512.

MLA: compressed kv cache (kv_lora_rank + rope_head_dim per token), absorbed
projections at decode. First layer dense (d_ff=12288). EP shards routed
experts over the `model` axis. [arXiv:2405.04434; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,           # MLA: per-head values decoded from shared latent
    d_ff=12288,                 # dense (first) layer FFN
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    moe_top_k=6,
    moe_groups=16,    # group-local dispatch (§Perf deepseek EXP-A)
    moe_d_ff=1536,
    prefix_pattern=(("attn", "mlp"),),
    pattern=(("attn", "moe"),),
    num_periods=59,
    act="silu",
    mlp_gated=True,
    supports_long_context=True,
    notes="MLA cache is (S, 512+64) per layer — O(seq·576); seq-sharded at 500k",
)
