"""Architecture + shape configuration system.

Every assigned architecture gets a module in ``repro.configs`` exposing
``CONFIG: ArchConfig``. The registry maps ``--arch <id>`` names to configs.

Shapes are the four assigned input-shape cells. ``input_specs()`` builds
``jax.ShapeDtypeStruct`` stand-ins for every model input so the multi-pod
dry-run can lower/compile without allocating anything.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Layer-kind vocabulary for hybrid block patterns.
#   mixer kinds: "attn", "attn_local", "attn_global", "mamba", "slstm", "mlstm"
#   ffn kinds:   "mlp", "moe", "none"
# A pattern is a tuple of (mixer, ffn) pairs; the full layer list is
#   prefix_pattern + pattern * num_periods + suffix_pattern
# --------------------------------------------------------------------------

LayerKind = tuple[str, str]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # ---- attention variants -------------------------------------------------
    attn_type: str = "gqa"           # gqa | mla
    rope_variant: str = "rope"       # rope | mrope | none
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0            # partial rotary (stablelm: 0.25)
    sliding_window: int = 0          # 0 = full attention (applies to attn_local too)
    qk_norm: bool = False

    # ---- MLA (deepseek) ------------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # ---- MoE -----------------------------------------------------------------
    num_experts: int = 0             # routed experts
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_groups: int = 0              # >0: group-local dispatch (see layers.moe_fwd)

    # ---- hybrid / pattern ----------------------------------------------------
    prefix_pattern: tuple[LayerKind, ...] = ()
    pattern: tuple[LayerKind, ...] = ()   # one period; empty -> (("attn","mlp"),)
    num_periods: int = 0                  # 0 -> num_layers // len(pattern)
    suffix_pattern: tuple[LayerKind, ...] = ()

    # ---- SSM (mamba) ---------------------------------------------------------
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0           # 0 -> ceil(d_model/16)

    # ---- xLSTM ---------------------------------------------------------------
    xlstm_proj_factor: float = 2.0   # mLSTM up-projection factor
    xlstm_conv: int = 4

    # ---- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500          # post-conv audio frames
    max_positions: int = 32_768      # learned-position table (decoder)

    # ---- modality frontend stub ----------------------------------------------
    frontend: str = "none"           # none | patches | audio_frames
    num_patches: int = 0             # vlm: patch embeddings per sample

    # ---- misc ----------------------------------------------------------------
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    sandwich_norm: bool = False      # gemma3 pre+post norms
    mlp_gated: bool = True
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    # long-context applicability: archs with pure full attention skip long_500k
    supports_long_context: bool = False
    # sharding policy: False -> pure DP/FSDP for single-pod training (small
    # d_model archs where TP means replicated attention compute and
    # Megatron-style activation all-reduces; see EXPERIMENTS.md §Perf)
    prefer_tp: bool = True
    notes: str = ""

    # -------------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def layer_kinds(self) -> tuple[LayerKind, ...]:
        pat = self.pattern or (("attn", "mlp"),)
        periods = self.num_periods or (
            (self.num_layers - len(self.prefix_pattern) - len(self.suffix_pattern))
            // len(pat)
        )
        kinds = self.prefix_pattern + pat * periods + self.suffix_pattern
        assert len(kinds) == self.num_layers, (
            f"{self.name}: pattern yields {len(kinds)} layers, want {self.num_layers}"
        )
        return kinds

    @property
    def resolved_num_periods(self) -> int:
        pat = self.pattern or (("attn", "mlp"),)
        return self.num_periods or (
            (self.num_layers - len(self.prefix_pattern) - len(self.suffix_pattern))
            // len(pat)
        )

    @property
    def resolved_pattern(self) -> tuple[LayerKind, ...]:
        return self.pattern or (("attn", "mlp"),)

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.api import count_params  # lazy: avoid cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.api import count_params
        return count_params(self, active_only=True)

    def reduced(self, **over) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = self.resolved_pattern
        small: dict[str, Any] = dict(
            num_layers=len(self.prefix_pattern) + len(pat) * 2 + len(self.suffix_pattern),
            num_periods=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab_size=128,
            encoder_seq=16 if self.is_encoder_decoder else self.encoder_seq,
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            max_positions=64,
            num_patches=4 if self.frontend == "patches" else 0,
            sliding_window=8 if self.sliding_window else 0,
        )
        if self.num_experts:
            small.update(num_experts=8, num_shared_experts=min(self.num_shared_experts, 2),
                         moe_top_k=min(self.moe_top_k, 2), moe_d_ff=32)
        if self.attn_type == "mla":
            small.update(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
                         qk_rope_head_dim=8, v_head_dim=16, head_dim=0)
        small.update(over)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}

ARCH_IDS: tuple[str, ...] = (
    "qwen2-vl-7b", "stablelm-3b", "granite-34b", "gemma3-1b", "h2o-danube-1.8b",
    "whisper-large-v3", "deepseek-v2-236b", "qwen2-moe-a2.7b",
    "jamba-1.5-large-398b", "xlstm-125m",
)

_MODULE_FOR: dict[str, str] = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "stablelm-3b": "stablelm_3b",
    "granite-34b": "granite_34b",
    "gemma3-1b": "gemma3_1b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "whisper-large-v3": "whisper_large_v3",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "xlstm-125m": "xlstm_125m",
}


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.CONFIG


def cells(include_long: bool = True) -> list[tuple[str, str]]:
    """All assigned (arch, shape) dry-run cells — 40 total."""
    out: list[tuple[str, str]] = []
    for a in ARCH_IDS:
        for s in SHAPES:
            out.append((a, s))
    if not include_long:
        out = [(a, s) for a, s in out if s != "long_500k"]
    return out


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k only runs for sub-quadratic archs (see DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Model inputs for one step of the given kind.

    train:   tokens/labels (B, S) [+ frontend embeds, + mrope positions]
    prefill: tokens (B, S) [+ ...]; returns logits for the last position
    decode:  token (B, 1) + pos (B,) + KV cache holding ``seq_len`` context
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    specs: dict[str, Any] = {}

    if shape.kind in ("train", "prefill"):
        specs["tokens"] = sd((B, S), i32)
        if shape.kind == "train":
            specs["labels"] = sd((B, S), i32)
        if cfg.frontend == "patches":
            specs["patch_embeds"] = sd((B, cfg.num_patches, cfg.d_model), cfg.dtype)
        if cfg.rope_variant == "mrope":
            specs["position_ids"] = sd((3, B, S), i32)
        if cfg.is_encoder_decoder:
            specs["frames"] = sd((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    else:  # decode
        from repro.models.api import cache_specs  # lazy import
        specs["token"] = sd((B, 1), i32)
        specs["pos"] = sd((B,), i32)
        specs["cache"] = cache_specs(cfg, batch=B, max_seq=S)
        if cfg.rope_variant == "mrope":
            specs["position_ids"] = sd((3, B, 1), i32)
        # enc-dec: the cross-attention k/v live inside the cache (computed at
        # prefill); no frames are re-encoded per decode step.
    return specs
