"""whisper-large-v3 [audio] — enc-dec, 32L(+32 enc) d_model=1280 20H d_ff=5120
vocab=51866. Conv frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (1500 frames). Non-gated GeLU MLP, LayerNorm, learned
positions (rope off). [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    prefer_tp=False,
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    rope_variant="none",
    is_encoder_decoder=True,
    num_encoder_layers=32,
    encoder_seq=1500,
    frontend="audio_frames",
    norm="layernorm",
    act="gelu",
    mlp_gated=False,
    supports_long_context=False,
    notes="decoder self-attn full; cross-attn to 1500 encoder frames",
)
