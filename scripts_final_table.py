"""Regenerate the EXPERIMENTS.md optimized-vs-baseline summary (run after
sweeps complete): prints per-cell bound seconds and speedups."""
import json

def load(path):
    uniq = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("status") == "ok":
            uniq[(r["arch"], r["shape"], r["mesh"])] = r
    return uniq

base = load("results/dryrun.jsonl")
opt = load("results/dryrun_opt.jsonl")
print(f"{'cell':55s} {'base bound':>10s} {'opt bound':>10s} {'x':>6s} {'opt frac':>8s}")
speedups = []
for key in sorted(base):
    if key not in opt:
        continue
    b = max(base[key][k] for k in ("t_compute_s","t_memory_s","t_collective_s"))
    o = max(opt[key][k] for k in ("t_compute_s","t_memory_s","t_collective_s"))
    x = b / o if o else float("inf")
    speedups.append(x)
    tag = f"{key[0]} {key[1]} [{key[2]}]"
    print(f"{tag:55s} {b:10.3f} {o:10.3f} {x:6.2f} {opt[key]['roofline_fraction']:8.3f}")
import statistics
print(f"\ngeomean speedup: {statistics.geometric_mean(speedups):.2f}x over {len(speedups)} cells")
